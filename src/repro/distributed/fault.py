"""Fault tolerance: heartbeats, straggler detection, elastic restart.

Single-controller view (the CPU container stands in for the coordinator):

* ``StepMonitor`` wraps step execution — per-step wall-time heartbeat,
  straggler flagging (> k x rolling median), failure counting.
* ``FaultTolerantRunner`` drives a train loop: periodic async checkpoints,
  failure capture (a worker exception == lost node), restore-and-continue,
  and ELASTIC restart — the checkpoint saved under one mesh is re-laid onto
  a smaller/larger mesh via checkpoint.restore(shardings=new).
* ``FailureInjector`` deterministically raises at chosen steps (tests).

On a real multi-pod deployment the same logic runs in the per-slice
coordinator; jax.distributed heartbeats replace the in-process clock, and
the elastic path re-invokes `make_production_mesh` with the surviving pod
count.  All decision logic below is pure host Python and fully unit-tested.
"""
from __future__ import annotations

import dataclasses
import statistics
import threading
import time
from typing import Callable, List, Optional


class WorkerFailure(RuntimeError):
    """Raised when a (simulated or real) worker dies mid-step."""


@dataclasses.dataclass
class StepRecord:
    step: int
    seconds: float
    straggler: bool


class StepMonitor:
    """Heartbeat + straggler detection over step wall-times."""

    def __init__(self, straggler_factor: float = 3.0, window: int = 32):
        self.factor = straggler_factor
        self.window = window
        self.records: List[StepRecord] = []
        self.last_heartbeat = time.time()

    def observe(self, step: int, seconds: float) -> StepRecord:
        recent = [r.seconds for r in self.records[-self.window:]]
        med = statistics.median(recent) if recent else seconds
        rec = StepRecord(step, seconds,
                         straggler=bool(recent) and seconds > self.factor * med)
        self.records.append(rec)
        self.last_heartbeat = time.time()
        return rec

    @property
    def stragglers(self) -> List[StepRecord]:
        return [r for r in self.records if r.straggler]

    def healthy(self, timeout: float) -> bool:
        return (time.time() - self.last_heartbeat) < timeout


class FailureInjector:
    def __init__(self, fail_at_steps=(), exc=WorkerFailure):
        self.fail_at = set(fail_at_steps)
        self.exc = exc
        self.fired = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise self.exc(f"injected node failure at step {step}")


@dataclasses.dataclass
class RunReport:
    steps_done: int
    restarts: int
    stragglers: int
    losses: List[float]


class FaultTolerantRunner:
    """Checkpointed, restartable training driver.

    run() executes ``step_fn(state, batch) -> (state, loss)`` for
    ``total_steps``, checkpointing every ``ckpt_every``; on WorkerFailure it
    restores the latest checkpoint (optionally onto a new mesh via
    ``reshard_fn``) and continues.  ``max_restarts`` bounds the retry loop.

    ``ckpt_codec`` selects a registry codec for checkpoint payloads
    (restore then decodes through the batched DecodePlan path), and
    ``sync_pipeline`` — a ``diloco.OuterSyncPipeline`` — lets an in-flight
    compressed outer sync DRAIN concurrently with the compressed restore:
    on failure the pending collective is released to finish in its waiter
    thread while ``checkpoint.restore`` decodes, and joined only after the
    restored state is live (restore + drain share one device budget
    instead of serializing).
    """

    def __init__(self, step_fn: Callable, ckpt_dir: str, ckpt_every: int = 10,
                 monitor: Optional[StepMonitor] = None,
                 injector: Optional[FailureInjector] = None,
                 reshard_fn: Optional[Callable] = None,
                 max_restarts: int = 3, async_ckpt: bool = True,
                 ckpt_codec: str = "none", sync_pipeline=None):
        self.step_fn = step_fn
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.monitor = monitor or StepMonitor()
        self.injector = injector
        self.reshard_fn = reshard_fn
        self.max_restarts = max_restarts
        self.async_ckpt = async_ckpt
        self.ckpt_codec = ckpt_codec
        self.sync_pipeline = sync_pipeline

    def run(self, state, batches, total_steps: int) -> tuple:
        from repro.checkpoint import checkpoint as ckpt
        restarts = 0
        losses: List[float] = []
        step = 0
        pending = None
        # resume if a checkpoint exists (restart-from-scratch case)
        latest = ckpt.latest_step(self.ckpt_dir)
        if latest is not None:
            state = ckpt.restore(self.ckpt_dir, latest, state)
            step = latest
        it = iter(batches)
        while step < total_steps:
            try:
                batch = next(it)
                if self.injector is not None:
                    self.injector.maybe_fail(step)
                t0 = time.time()
                state, loss = self.step_fn(state, batch)
                rec = self.monitor.observe(step, time.time() - t0)
                losses.append(float(loss))
                step += 1
                if step % self.ckpt_every == 0:
                    if pending is not None:
                        pending.join()
                    pending = ckpt.save(self.ckpt_dir, step, state,
                                        codec=self.ckpt_codec,
                                        async_=self.async_ckpt)
            except WorkerFailure:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                if pending is not None:
                    pending.join()
                    pending = None
                # release any in-flight outer sync: its waiter thread keeps
                # draining the collective WHILE restore decodes the
                # compressed checkpoint below; joined after restore.
                th = None
                if (self.sync_pipeline is not None
                        and self.sync_pipeline.in_flight):
                    th = threading.Thread(target=self.sync_pipeline.drain,
                                          daemon=True)
                    th.start()
                latest = ckpt.latest_step(self.ckpt_dir)
                if latest is None:
                    if th is not None:
                        th.join()
                    step = 0  # no checkpoint yet: restart from scratch
                    continue
                if self.reshard_fn is not None:
                    state = self.reshard_fn(
                        ckpt.restore(self.ckpt_dir, latest, state))
                else:
                    state = ckpt.restore(self.ckpt_dir, latest, state)
                if th is not None:
                    th.join()
                step = latest
        if pending is not None:
            pending.join()
        report = RunReport(steps_done=step, restarts=restarts,
                           stragglers=len(self.monitor.stragglers),
                           losses=losses)
        return state, report
