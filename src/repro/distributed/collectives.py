"""Compressed collectives: gradient sync lowered through the DecodePlan IR.

The paper's thesis — decompression throughput is worth engineering for, and
decode should ride the same all-thread pipeline as every other kernel —
applied to the collective plane.  Inter-pod links (DCI) are an order of
magnitude slower than intra-pod ICI, so the bytes crossing them are the
scarce resource; this module makes the *wire format* of a cross-pod
all-reduce a registry-codec compressed stream and the *receive path* a
``plan.dispatch`` decode with a fused dequant→reduce epilogue:

  encode (device, in-jit)   each member quantizes its local delta
                            (int8 per-block-128 scales, or top-k values +
                            1-bit index bitmap) and packs it into the
                            bitpack codec's EXACT wire layout
                            (:func:`pack_bits_rows` mirrors
                            ``encoders.pack_bits`` bit for bit — a blob
                            built here decodes through any registry
                            backend).
  gather (the collective)   ``plan.gather_member_tables`` all-gathers the
                            compressed bytes plus per-member chunk tables
                            over the mesh axis inside ``shard_map`` — the
                            only f32 crossing the axis is the per-block
                            scale column.
  decode (DecodePlan)       ONE :func:`repro.core.plan.dispatch` lowering
                            per leaf decodes every member's rows
                            shard-locally; ``plan.dispatch`` stays the
                            repo's only ``ops.decode`` call site.
  epilogue (fused)          a ``harness.Epilogue`` fused into the dispatch
                            dequantizes ``(x - zero) * scale`` and reduces
                            over the member axis INSIDE the decode
                            computation — the per-member dequantized
                            deltas and the averaged f32 tree never
                            materialize for the consumer; the DiLoCo outer
                            step (distributed/diloco.py) and the
                            ``grad_compressor`` hook consume decode
                            outputs directly.

Wire cost per member for an all-gather collective over n members (exact,
computed from the same geometry the encoder uses — :func:`wire_report`):

    f32 ring all-reduce : 2 * 4B * (n-1)/n
    int8 + scales       : (B + 4B/128) * (n-1)          (~3.9x less, n=2)
    top-k 1% + bitmap   : (2k + B/8) * (n-1)            (~27x less, n=2)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import plan as plan_mod
from repro.core.engine import EngineConfig
from repro.kernels.harness import Epilogue
from repro.optim import grad_compress as gc

WIRE_CODEC = "bitpack"
WIRE_BITS = 8          # int8 deltas, biased to [0, 254]
WIRE_ZERO = 127.0
MASK_CHUNK = 2048      # top-k bitmap elements per wire chunk (256 B rows)


def _default_config() -> EngineConfig:
    return EngineConfig()


# --------------------------------------------------------------------------
# device-side wire encode (the bitpack layout, built in-jit)
# --------------------------------------------------------------------------


def pack_bits_rows(vals: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Pack each row of ``vals`` LSB-first into uint32 words — the exact
    device mirror of ``encoders.pack_bits`` per chunk row.

    ``vals``: (n_chunks, chunk_elems) unsigned ints < 2**bits.  ``bits``
    must divide 32 (the collective wire uses 8 for int8 payloads and 1 for
    top-k bitmaps); rows are zero-padded up to a whole word.  Bit-fields of
    distinct elements are disjoint, so the word is just the OR of shifted
    lanes — fully vectorized, no scatter.
    """
    if 32 % bits:
        raise ValueError(f"wire bits must divide 32, got {bits}")
    per = 32 // bits
    n, e = vals.shape
    pad = (-e) % per
    v = vals.astype(jnp.uint32) & jnp.uint32((1 << bits) - 1 if bits < 32
                                            else 0xFFFFFFFF)
    if pad:
        v = jnp.pad(v, ((0, 0), (0, pad)))
    v = v.reshape(n, -1, per)
    return functools.reduce(
        jnp.bitwise_or,
        [v[:, :, i] << jnp.uint32(i * bits) for i in range(per)])


def wire_dev(words: jnp.ndarray, *, chunk_elems: int,
             bits: int) -> Dict[str, Any]:
    """Build the ``dispatch``-consumable device pytree for a bitpack wire
    table, entirely on device.

    Matches ``ops.table_inputs(encoders.compress(arr, "bitpack", ...))``
    byte for byte (lane-aligned ``comp`` padding included), so the wire a
    collective moves IS a registry blob: the conformance suite's decoders
    accept it unchanged.
    """
    n, w = words.shape
    want = int(np.ceil((w * 4 + 8) / 128) * 128)     # format.to_device pad
    words_p = jnp.pad(words, ((0, 0), (0, want // 4 - w)))
    comp = lax.bitcast_convert_type(words_p, jnp.uint8).reshape(n, want)
    return {
        "comp": comp,
        "comp_words": words_p,
        "comp_lens": jnp.full((n,), w * 4, jnp.int32),
        "out_lens": jnp.full((n,), chunk_elems, jnp.int32),
        "bitpack_bits": jnp.full((1,), bits, jnp.int32),
    }


def quantized_wire(x: jnp.ndarray):
    """Encode one leaf into the int8 bitpack wire: (dev pytree, scales).

    ``quantize_leaf``'s int8 blocks are biased to [0, 254] and packed at 8
    bits — one quantization block per wire chunk, so the per-chunk decode
    epilogue's ``scale_key`` operand broadcasts ``(nb, 1) * (nb, QBLOCK)``.
    """
    q, s = gc.quantize_leaf(x)
    u = (q.astype(jnp.int32) + int(WIRE_ZERO)).astype(jnp.uint32)
    words = pack_bits_rows(u, WIRE_BITS)
    return wire_dev(words, chunk_elems=gc.QBLOCK, bits=WIRE_BITS), s


# --------------------------------------------------------------------------
# fused epilogues (dequant -> member reduce inside the decode dispatch)
# --------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _member_reduce(n_members: int, mean: bool):
    """Epilogue fn: fold the gathered member axis INSIDE the dispatch.

    Cached so the closure's identity is stable — ``Epilogue`` compares
    ``fn`` by identity for jit caching."""

    def fn(out, dev):
        r = out.reshape((n_members, -1) + out.shape[1:]).sum(axis=0)
        return r / n_members if mean else r

    return fn


@functools.lru_cache(maxsize=None)
def _mask_scatter_reduce(n_members: int, mean: bool):
    """Epilogue fn for the top-k wire: decoded 1-bit masks -> dense deltas.

    ``out`` is the (n*nc, MASK_CHUNK) decoded bitmap; the surviving values
    ride the device pytree under ``topk_vals`` (n, k) in index order.  Mask
    positions are recovered with a prefix sum, values gathered into place,
    and the member axis reduced — all inside the decode computation."""

    def fn(out, dev):
        vals = dev["topk_vals"].astype(jnp.float32)          # (n, k)
        m = out.reshape(n_members, -1).astype(jnp.int32)     # (n, size_pad)
        cum = jnp.clip(jnp.cumsum(m, axis=1) - 1, 0, vals.shape[1] - 1)
        dense = jnp.take_along_axis(vals, cum, axis=1) * m
        r = dense.sum(axis=0)
        return r / n_members if mean else r

    return fn


# --------------------------------------------------------------------------
# the collectives (call INSIDE shard_map)
# --------------------------------------------------------------------------


def compressed_psum(x: jnp.ndarray, axis_name: str, *,
                    config: Optional[EngineConfig] = None, tune=None,
                    mean: bool = False) -> jnp.ndarray:
    """int8-wire all-reduce over ``axis_name`` (call inside ``shard_map``).

    Encodes the local leaf into the bitpack wire, all-gathers compressed
    bytes + chunk tables (``plan.gather_member_tables``), and lowers the
    receive path through ``plan.dispatch`` with a fused
    dequant→member-reduce ``Epilogue`` — the summed (or ``mean``ed) f32
    leaf is the decode output itself.

    ``tune`` must be resolved OUTSIDE an enclosing jit trace
    (``tuning.kernel_tune(WIRE_CODEC, 1, config.tune)``); ``None`` resolves
    it here, which is only safe when called eagerly.
    """
    config = config or _default_config()
    if tune is None:
        from repro.core import tuning
        tune = tuning.kernel_tune(WIRE_CODEC, 1, config.tune)
    dev, s = quantized_wire(x)
    nb = dev["out_lens"].shape[0]
    dev = plan_mod.gather_member_tables(dev, axis_name, codec=WIRE_CODEC)
    n = dev["out_lens"].shape[0] // nb
    dev["wire_scale"] = lax.all_gather(s, axis_name).reshape(n * nb, 1)
    dev["wire_zero"] = jnp.float32(WIRE_ZERO)
    epi = Epilogue(out_dtype="float32", scale_key="wire_scale",
                   zero_key="wire_zero", fn=_member_reduce(n, mean))
    summed = plan_mod.dispatch(dev, config=config, codec=WIRE_CODEC,
                               width=1, chunk_elems=gc.QBLOCK,
                               bits=WIRE_BITS, epilogue=epi, tune=tune)
    return summed.reshape(-1)[: x.size].reshape(x.shape)


def topk_psum(x: jnp.ndarray, residual: jnp.ndarray, axis_name: str, *,
              frac: float = 0.01, config: Optional[EngineConfig] = None,
              tune=None, mean: bool = False):
    """Top-k + error-feedback all-reduce (call inside ``shard_map``).

    Wire per member: exactly-k f16 values (index order) + a 1-bit index
    bitmap packed through the bitpack codec.  The gathered bitmaps decode
    through ONE ``plan.dispatch``; the fused epilogue scatters each
    member's values into place and reduces — returns
    ``(reduced_dense, new_residual)`` with the residual accumulated
    locally (momentum-correct SGD-EF).
    """
    config = config or _default_config()
    if tune is None:
        from repro.core import tuning
        tune = tuning.kernel_tune(WIRE_CODEC, 1, config.tune)
    acc = x.astype(jnp.float32) + residual
    flat = acc.reshape(-1)
    k = max(1, int(flat.size * frac))
    mask, kept = gc.topk_select(flat, k)
    new_residual = (flat - kept).reshape(x.shape)
    idx = jnp.nonzero(mask, size=k, fill_value=0)[0]   # ascending -> order
    vals = flat[idx].astype(jnp.float16)               # the f16 wire grid
    pad = (-flat.size) % MASK_CHUNK
    maskp = jnp.pad(mask.astype(jnp.uint32), (0, pad)).reshape(-1, MASK_CHUNK)
    dev = wire_dev(pack_bits_rows(maskp, 1), chunk_elems=MASK_CHUNK, bits=1)
    nc = dev["out_lens"].shape[0]
    dev = plan_mod.gather_member_tables(dev, axis_name, codec=WIRE_CODEC)
    n = dev["out_lens"].shape[0] // nc
    dev["topk_vals"] = lax.all_gather(vals, axis_name)
    epi = Epilogue(fn=_mask_scatter_reduce(n, mean))
    dense = plan_mod.dispatch(dev, config=config, codec=WIRE_CODEC,
                              width=1, chunk_elems=MASK_CHUNK, bits=1,
                              epilogue=epi, tune=tune)
    return dense[: flat.size].reshape(x.shape), new_residual


def make_tree_reduce(mesh, axis: str = "pod", *, wire: str = "int8",
                     frac: float = 0.01,
                     config: Optional[EngineConfig] = None):
    """Jit-able tree-wise compressed mean-all-reduce over one mesh axis.

    Input tree leaves carry a leading per-member axis of size
    ``mesh.shape[axis]`` sharded over it (per-pod delta replicas in the
    DiLoCo outer loop).  Returns ``reduce(tree, residuals=None) ->
    (mean_tree, new_residuals)``: the member-mean of every leaf, computed
    through the compressed wire selected by ``wire``:

      "int8"  — :func:`compressed_psum` (leaves smaller than one quant
                block ride an uncompressed ``lax.psum``)
      "topk"  — :func:`topk_psum` with per-member error-feedback residuals
                (``residuals`` required: same structure, leading member
                axis; returned updated)
      "none"  — plain f32 ``lax.psum`` (the baseline wire)

    Kernel knobs are resolved eagerly at build time so the returned
    function is safe to trace inside an outer jit.
    """
    if wire not in ("int8", "topk", "none"):
        raise ValueError(f"unknown wire {wire!r}")
    config = config or _default_config()
    n = int(mesh.shape[axis])
    from repro.core import tuning
    tune = tuning.kernel_tune(WIRE_CODEC, 1, config.tune)

    def reduce_fn(tree, residuals=None):
        if wire == "topk" and residuals is None:
            raise ValueError("wire='topk' needs error-feedback residuals")
        flat, tdef = jax.tree.flatten(tree)
        res_flat = (tdef.flatten_up_to(residuals)
                    if residuals is not None else [None] * len(flat))

        def body(*leaves):
            ms, rs = leaves[: len(flat)], leaves[len(flat):]
            outs, res_out = [], []
            for i, member in enumerate(ms):
                x = member[0]
                r = rs[i][0] if rs else None
                if wire == "none" or x.size < gc.QBLOCK:
                    red = lax.psum(x.astype(jnp.float32), axis) / n
                    nr = r
                elif wire == "topk":
                    red, nr = topk_psum(x, r, axis, frac=frac,
                                        config=config, tune=tune, mean=True)
                else:
                    red, nr = compressed_psum(x, axis, config=config,
                                              tune=tune, mean=True), r
                outs.append(red[None])
                if rs:
                    res_out.append(nr[None])
            return tuple(outs) + tuple(res_out)

        args = list(flat)
        if residuals is not None:
            args += res_flat
        specs = tuple(P(axis) for _ in args)
        out = shard_map(body, mesh=mesh, in_specs=specs, out_specs=specs,
                        check_rep=False)(*args)
        mean_tree = tdef.unflatten(
            [o[0] for o in out[: len(flat)]])
        new_res = (tdef.unflatten(list(out[len(flat):]))
                   if residuals is not None else None)
        return mean_tree, new_res

    return reduce_fn


# --------------------------------------------------------------------------
# wire-faithful grad compressor (the per-step grad_compressor hook)
# --------------------------------------------------------------------------


def make_wire_compressor(config: Optional[EngineConfig] = None):
    """Gradient compressor whose dequantized output IS a decode output.

    Drop-in for the ``grad_compressor`` hook in
    ``launch.steps.build_train_step``: each leaf is encoded into the int8
    bitpack wire on device and decoded back through ``plan.dispatch`` with
    the fused dequant epilogue — the optimizer consumes exactly the values
    a receiving pod would decode off the wire (numerically identical to
    ``grad_compress.quantize_grads``, but proved through the real decode
    path).  Leaves smaller than one quant block pass through.
    """
    config = config or _default_config()
    from repro.core import tuning
    tune = tuning.kernel_tune(WIRE_CODEC, 1, config.tune)

    def compressor(grads):
        def qdq(g):
            if g.size < gc.QBLOCK:
                return g
            dev, s = quantized_wire(g)
            dev["wire_scale"] = s
            dev["wire_zero"] = jnp.float32(WIRE_ZERO)
            epi = Epilogue(out_dtype="float32", scale_key="wire_scale",
                           zero_key="wire_zero")
            table = plan_mod.dispatch(
                dev, config=config, codec=WIRE_CODEC, width=1,
                chunk_elems=gc.QBLOCK, bits=WIRE_BITS, epilogue=epi,
                tune=tune)
            return table.reshape(-1)[: g.size].reshape(g.shape).astype(g.dtype)

        return jax.tree.map(qdq, grads)

    return compressor


# --------------------------------------------------------------------------
# exact wire-bytes accounting (same geometry as the encoders above)
# --------------------------------------------------------------------------


def leaf_wire_bytes(size: int, *, wire: str, frac: float = 0.01) -> float:
    """Per-member all-gather payload bytes for one leaf of ``size`` f32
    elements — computed from the SAME chunk geometry the device encoders
    use, so estimate == bytes actually gathered."""
    if wire == "none" or size < gc.QBLOCK:
        return float(size * 4)
    nb = -(-size // gc.QBLOCK)
    if wire == "int8":
        words = (gc.QBLOCK * WIRE_BITS + 31) // 32
        return float(nb * (words * 4 + 4))          # packed rows + scales
    if wire == "topk":
        k = max(1, int(size * frac))
        padded = -(-size // MASK_CHUNK) * MASK_CHUNK
        return float(k * 2 + padded // 8)           # f16 values + bitmap
    raise ValueError(f"unknown wire {wire!r}")


def wire_report(tree, n_members: int, *, wire: str = "int8",
                frac: float = 0.01) -> Dict[str, float]:
    """Exact bytes-on-wire per member for one tree sync, vs the f32 ring
    all-reduce baseline (``ratio`` = baseline / compressed)."""
    sizes = [int(np.prod(l.shape)) for l in jax.tree.leaves(tree)]
    nbytes = sum(s * 4 for s in sizes)
    payload = sum(leaf_wire_bytes(s, wire=wire, frac=frac) for s in sizes)
    compressed = payload * (n_members - 1)
    f32 = gc.wire_bytes_f32_allreduce(nbytes, n_members)
    return {"f32_ring_bytes": f32, "wire_bytes": compressed,
            "ratio": f32 / max(1.0, compressed)}
