# Distribution layer: sharding rules, compressed collectives, fault tolerance.
