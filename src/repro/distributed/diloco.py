"""DiLoCo-style cross-pod training: local inner steps + compressed outer sync.

Inter-pod links are slow; instead of an all-pod gradient psum every step,
each pod trains independently (DP over its intra-pod 'data' axis) for H
inner steps, then pods reconcile with ONE compressed collective:

    inner:  per-pod AdamW on per-pod parameter replicas
            (params carry a leading (n_pods,) axis sharded over 'pod';
            the inner step is vmapped over it, so no 'pod' collective
            is emitted at all)
    outer:  delta = local - anchor per pod; int8-compressed all-reduce
            (optim/grad_compress.compressed_psum) across 'pod'; anchor
            updated with Nesterov momentum on the averaged delta (DiLoCo,
            arXiv:2311.08105); all pods rebase onto the new anchor.

Wire cost per outer sync: params/4 bytes vs params*2*(H steps) for naive
per-step bf16 grad sync — a ~8H x reduction on the inter-pod links
(EXPERIMENTS.md §Perf quantifies this with the dry-run collective parser).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.optim import grad_compress


@dataclasses.dataclass(frozen=True)
class DiLoCoConfig:
    inner_steps: int = 16
    outer_lr: float = 0.7
    outer_momentum: float = 0.9
    compress: bool = True


def replicate_for_pods(tree, n_pods: int, mesh: Mesh = None):
    """Add a leading (n_pods,) member axis to every leaf."""
    def rep(x):
        y = jnp.broadcast_to(x[None], (n_pods,) + x.shape)
        if mesh is not None:
            y = jax.device_put(y, NamedSharding(
                mesh, P(*("pod",) + (None,) * x.ndim)))
        return y
    return jax.tree.map(rep, tree)


def make_inner_step(train_step: Callable):
    """vmap a (params, opt, batch)->(params, opt, loss) step over the pod
    axis. Batch must carry the same leading (n_pods,) axis."""
    return jax.vmap(train_step)


def make_outer_sync(mesh: Mesh, cfg: DiLoCoConfig):
    """Returns sync(pod_params, anchor, outer_mom) -> (pod_params, anchor,
    outer_mom).  pod_params: leaves (n_pods, ...) sharded over 'pod';
    anchor/outer_mom: plain replicated trees."""
    n_pods = mesh.shape["pod"]
    tree_cpsum = grad_compress.make_compressed_psum_fn(mesh, "pod")

    def sync(pod_params, anchor, outer_mom):
        # per-pod delta from the anchor
        deltas = jax.tree.map(lambda p, a: p - a[None].astype(p.dtype),
                              pod_params, anchor)
        if cfg.compress:
            summed = tree_cpsum(deltas)       # int8 wire across pods
        else:
            summed = jax.tree.map(
                lambda d: jnp.broadcast_to(jnp.sum(d, 0, keepdims=True),
                                           d.shape), deltas)
        avg = jax.tree.map(lambda s: s[0].astype(jnp.float32) / n_pods, summed)
        # Nesterov outer step on the averaged delta
        new_mom = jax.tree.map(
            lambda m, g: cfg.outer_momentum * m + g, outer_mom, avg)
        new_anchor = jax.tree.map(
            lambda a, m, g: (a.astype(jnp.float32)
                             + cfg.outer_lr * (cfg.outer_momentum * m + g)
                             ).astype(a.dtype),
            anchor, new_mom, avg)
        new_pod_params = replicate_for_pods(new_anchor, n_pods)
        return new_pod_params, new_anchor, new_mom

    return sync


def init_outer_state(params):
    anchor = jax.tree.map(lambda x: x, params)
    outer_mom = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
    return anchor, outer_mom
