"""DiLoCo-style cross-pod training: local inner steps + compressed outer sync.

Inter-pod links are slow; instead of an all-pod gradient psum every step,
each pod trains independently (DP over its intra-pod 'data' axis) for H
inner steps, then pods reconcile with ONE compressed collective:

    inner:  per-pod AdamW on per-pod parameter replicas
            (params carry a leading (n_pods,) axis sharded over 'pod';
            the inner step is vmapped over it, so no 'pod' collective
            is emitted at all)
    outer:  delta = local - anchor per pod; the delta tree crosses the
            pod axis as registry-codec compressed bytes
            (distributed/collectives.make_tree_reduce — int8 bitpack
            wire or top-k values + 1-bit bitmap with error feedback),
            decoded shard-locally through ``plan.dispatch`` with the
            dequant→member-mean fused into the decode epilogue; the
            Nesterov outer step (DiLoCo, arXiv:2311.08105) consumes the
            decode output directly and all pods rebase onto the new
            anchor.
    overlap: ``OuterSyncPipeline`` double-buffers the sync — the
            collective for window W runs concurrently with window W+1's
            inner steps, and the delayed outer update is merged with a
            streaming-DiLoCo-style correction
            (merged = synced + (now - snapshot)).

Wire cost per outer sync: ~params/4 bytes (int8) or ~params/50 (top-k 1%)
vs params*2*(H steps) for naive per-step bf16 grad sync;
``collectives.wire_report`` computes the exact figures.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class DiLoCoConfig:
    inner_steps: int = 16
    outer_lr: float = 0.7
    outer_momentum: float = 0.9
    compress: bool = True
    wire: str = "int8"          # "int8" | "topk" | "none" (compress=False)
    topk_frac: float = 0.01


def replicate_for_pods(tree, n_pods: int, mesh: Mesh = None):
    """Add a leading (n_pods,) member axis to every leaf, placed over the
    mesh 'pod' axis when ``mesh`` is given.

    Works both eagerly (``device_put``) and under a jit trace
    (``with_sharding_constraint``) — the outer sync calls this inside jit,
    where a ``device_put`` placement would not stick.
    """
    def rep(x):
        y = jnp.broadcast_to(x[None], (n_pods,) + x.shape)
        if mesh is not None:
            sh = NamedSharding(mesh, P(*("pod",) + (None,) * x.ndim))
            if isinstance(y, jax.core.Tracer):
                y = jax.lax.with_sharding_constraint(y, sh)
            else:
                y = jax.device_put(y, sh)
        return y
    return jax.tree.map(rep, tree)


def make_inner_step(train_step: Callable):
    """vmap a (params, opt, batch)->(params, opt, loss) step over the pod
    axis. Batch must carry the same leading (n_pods,) axis."""
    return jax.vmap(train_step)


def init_outer_state(params, *, mesh: Mesh = None, cfg: DiLoCoConfig = None):
    """Outer-loop state dict: ``anchor`` (the reference params every pod
    rebases onto), f32 Nesterov ``outer_mom``, and — for the top-k wire —
    per-pod error-feedback ``residual`` trees carrying the same leading
    (n_pods,) axis as the pod params."""
    cfg = cfg or DiLoCoConfig()
    state = {
        "anchor": jax.tree.map(lambda x: x, params),
        "outer_mom": jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params),
        "residual": None,
    }
    if cfg.compress and cfg.wire == "topk":
        if mesh is None:
            raise ValueError("wire='topk' needs the mesh to place per-pod "
                             "error-feedback residuals")
        zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                             params)
        state["residual"] = replicate_for_pods(
            zeros, int(mesh.shape["pod"]), mesh)
    return state


def make_outer_sync(mesh: Mesh, cfg: DiLoCoConfig, *, config=None):
    """Returns sync(pod_params, outer) -> (pod_params, outer).

    ``pod_params``: leaves (n_pods, ...) sharded over 'pod'; ``outer``: the
    :func:`init_outer_state` dict.  The delta tree crosses the pod axis
    through the compressed wire selected by ``cfg.wire`` and the averaged
    delta is the decode output itself (dequant + member-mean fused into the
    dispatch epilogue); the rebase threads ``mesh`` through
    :func:`replicate_for_pods` so the new pod replicas keep their 'pod'
    NamedSharding.
    """
    from repro.distributed import collectives

    n_pods = int(mesh.shape["pod"])
    wire = cfg.wire if cfg.compress else "none"
    reduce_fn = collectives.make_tree_reduce(
        mesh, "pod", wire=wire, frac=cfg.topk_frac, config=config)

    def sync(pod_params, outer):
        anchor, outer_mom = outer["anchor"], outer["outer_mom"]
        # per-pod delta from the anchor
        deltas = jax.tree.map(
            lambda p, a: (p - a[None].astype(p.dtype)).astype(jnp.float32),
            pod_params, anchor)
        avg, new_res = reduce_fn(deltas, outer.get("residual"))
        # Nesterov outer step directly on the decode output
        new_mom = jax.tree.map(
            lambda m, g: cfg.outer_momentum * m + g, outer_mom, avg)
        new_anchor = jax.tree.map(
            lambda a, m, g: (a.astype(jnp.float32)
                             + cfg.outer_lr * (cfg.outer_momentum * m + g)
                             ).astype(a.dtype),
            anchor, new_mom, avg)
        new_pod_params = replicate_for_pods(new_anchor, n_pods, mesh)
        new_outer = {"anchor": new_anchor, "outer_mom": new_mom,
                     "residual": new_res}
        return new_pod_params, new_outer

    return sync


class OuterSyncPipeline:
    """Overlap the outer-sync collective with the next window's inner steps.

    Double-buffered sync state, the same prefetch-overlap discipline as
    ``core.store.stream_windows``: ``launch(pod_params, outer)`` snapshots
    the pod params and dispatches the (async) sync; the caller keeps
    running inner steps on the UN-synced params; ``finish(pod_params_now)``
    blocks only for whatever collective time the inner window didn't
    already hide and merges the delayed update streaming-DiLoCo style:

        merged = synced_params + (pod_params_now - snapshot)

    so inner progress made during the overlap window is preserved on top
    of the rebased anchor.

    ``link_rtt_s`` injects a deterministic inter-pod link round-trip into
    the completion signal (same injected-latency discipline as the blob
    store's backend ``read_delay``), making overlap measurable on CPU CI:
    ``stats()['overlap_frac'] = 1 - wait/collective``.
    """

    def __init__(self, sync_fn: Callable, *, link_rtt_s: float = 0.0):
        self.sync_fn = sync_fn
        self.link_rtt_s = link_rtt_s
        self._pending = None
        self.syncs = 0
        self.collective_s = 0.0
        self.wait_s = 0.0

    def launch(self, pod_params, outer) -> None:
        if self._pending is not None:
            raise RuntimeError("outer sync already in flight "
                               "(finish() or abandon() it first)")
        t0 = time.perf_counter()
        new_pod_params, new_outer = self.sync_fn(pod_params, outer)
        done = threading.Event()
        box = {"done_at": None}

        def waiter():
            jax.block_until_ready(
                (new_pod_params, new_outer["anchor"]))
            if self.link_rtt_s:
                time.sleep(self.link_rtt_s)
            box["done_at"] = time.perf_counter()
            done.set()

        th = threading.Thread(target=waiter, daemon=True)
        th.start()
        self._pending = (pod_params, new_pod_params, new_outer,
                         done, box, t0)

    @property
    def in_flight(self) -> bool:
        return self._pending is not None

    def finish(self, pod_params_now=None):
        """Block for the remaining collective time and return
        ``(merged_pod_params, new_outer)``.  With ``pod_params_now`` the
        delayed update is corrected for inner progress made during the
        overlap; without it the synced params are returned as-is."""
        if self._pending is None:
            raise RuntimeError("no outer sync in flight")
        snapshot, new_pod_params, new_outer, done, box, t0 = self._pending
        self._pending = None
        w0 = time.perf_counter()
        done.wait()
        self.wait_s += time.perf_counter() - w0
        self.collective_s += box["done_at"] - t0
        self.syncs += 1
        if pod_params_now is not None:
            new_pod_params = jax.tree.map(
                lambda synced, now, snap:
                    (synced.astype(jnp.float32)
                     + (now.astype(jnp.float32) - snap.astype(jnp.float32))
                     ).astype(synced.dtype),
                new_pod_params, pod_params_now, snapshot)
        return new_pod_params, new_outer

    def drain(self) -> None:
        """Wait out any in-flight sync without consuming its result — the
        fault path calls this so checkpoint restore can proceed while the
        pending collective completes in its waiter thread."""
        if self._pending is None:
            return
        _, _, _, done, box, t0 = self._pending
        self._pending = None
        w0 = time.perf_counter()
        done.wait()
        self.wait_s += time.perf_counter() - w0
        self.collective_s += box["done_at"] - t0

    def abandon(self) -> None:
        """Drop the in-flight sync immediately (its waiter thread finishes
        in the background); used when a failure invalidates the window."""
        self._pending = None

    def stats(self) -> dict:
        frac = (1.0 - self.wait_s / self.collective_s
                if self.collective_s > 0 else 0.0)
        return {"syncs": self.syncs, "collective_s": self.collective_s,
                "wait_s": self.wait_s, "overlap_frac": max(0.0, frac)}
