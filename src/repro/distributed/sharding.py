"""Sharding rules: logical-axis constraints + parameter PartitionSpec trees.

Model code annotates activations with *logical* axes via ``constrain`` —
a no-op unless a mesh context is installed (so smoke tests on 1 CPU device
run the exact same code).  The launch layer installs the context:

    with sharding.use_mesh(mesh):
        jax.jit(step, in_shardings=..., ...)

Logical axes: "dp" -> all batch axes present in the mesh (("pod","data") on
the multi-pod mesh, ("data",) single-pod), "model" -> tensor/expert axis.

Parameter specs are derived from pytree path names (regex table below):
TP over 'model' for attention heads / FFN hidden / vocab, EP over 'model'
for the MoE expert dimension, everything replicated over the DP axes
(optimizer state is additionally sharded over 'data' — ZeRO-1 — see
optim/adamw.py).
"""
from __future__ import annotations

import contextlib
import re
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX: dict = {"mesh": None, "policy": "tp"}


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], policy: str = "tp"):
    """policy: "tp" (default, model axis = tensor/expert parallel) or
    "dp" (fold the model axis into data parallelism: weights replicated,
    batch sharded 256-way — the right provisioning for small attn-free
    models where per-layer TP collectives dominate, §Perf hillclimb 1)."""
    prev = (_CTX["mesh"], _CTX["policy"])
    _CTX["mesh"] = mesh
    _CTX["policy"] = policy
    try:
        yield
    finally:
        _CTX["mesh"], _CTX["policy"] = prev


def current_mesh() -> Optional[Mesh]:
    return _CTX["mesh"]


def current_policy() -> str:
    return _CTX["policy"]


def dp_axes(mesh: Mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if _CTX["policy"] == "dp" and "model" in mesh.axis_names:
        axes = axes + ("model",)
    return axes


def _resolve(mesh: Mesh, axis):
    if axis is None:
        return None
    if axis == "dp":
        ax = dp_axes(mesh)
        return ax if len(ax) > 1 else (ax[0] if ax else None)
    return axis if axis in mesh.axis_names else None


def dp_groups(batch: int) -> int:
    """Number of DP shards dividing ``batch`` (1 without a mesh context).
    Used by the MoE layer to keep routing/sort local per shard."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return 1
    g = 1
    for a in dp_axes(mesh):
        if batch % (g * mesh.shape[a]) == 0:
            g *= mesh.shape[a]
    return g


def constrain(x, *axes):
    """with_sharding_constraint on logical axes; no-op without a mesh."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    spec = P(*(_resolve(mesh, a) for a in axes))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def decode_axis(mesh: Mesh) -> str:
    """The mesh axis decompression work partitions over.

    Decode streams are embarrassingly parallel (each chunk is independent),
    so they ride a data-parallel axis: 'data' when present, then 'pod',
    else the mesh's first axis.  Used by ``core.plan.execute_sharded`` as
    the default row-partition axis.
    """
    for a in ("data", "pod"):
        if a in mesh.axis_names:
            return a
    return mesh.axis_names[0]


def member_sharding(mesh: Mesh, axis: str = "pod",
                    ndim: int = 1) -> NamedSharding:
    """NamedSharding for per-member trees on the collective plane: leading
    member axis over ``axis`` (one replica slice per pod), trailing dims
    replicated.  Used for DiLoCo pod-param replicas, error-feedback
    residuals, and the gathered wire tables in tests."""
    return NamedSharding(mesh, P(axis, *([None] * (ndim - 1))))


def decode_out_sharding(mesh: Mesh, ndim: int = 1) -> NamedSharding:
    """NamedSharding placing a decoded array's leading dim over
    :func:`decode_axis` (trailing dims replicated) — the default *place*
    target for decoded token shards and other row-major outputs."""
    return NamedSharding(mesh, P(decode_axis(mesh), *([None] * (ndim - 1))))


# --------------------------------------------------------------------------
# parameter PartitionSpecs (regex on pytree path)
# --------------------------------------------------------------------------

# (path-regex, spec for the *unstacked* param); stacked block params get a
# leading None prepended automatically when rank exceeds the spec length.
_RULES = [
    (r"embed$",              ("model_last",)),        # (V, D): D over model
    (r"lm_head$",            ("model_last",)),        # (D, V): V over model
    (r"attn/w[qkv]$",        ("model_last",)),
    (r"attn/wo$",            ("model_first",)),
    (r"(mlp|shared|cmix)/w_(up|gate|ck)$",  ("model_last",)),
    (r"(mlp|shared|cmix)/w_(down|cv)$",     ("model_first",)),
    (r"cmix/w_cr$",          ("model_last",)),
    (r"moe/router$",         ("replicate",)),
    (r"moe/w_(up|gate|down)$", ("expert",)),          # (E, ., .): E over model
    (r"rwkv/w_(r|k|v|g|decay)$", ("model_last",)),
    (r"rwkv/w_o$",           ("model_first",)),
    (r"mamba/in_proj$",      ("model_last",)),
    (r"mamba/out_proj$",     ("model_first",)),
    (r"mamba/conv_w$",       ("model_last",)),
]


def _spec_for(path: str, ndim: int, shape, mesh: Mesh) -> P:
    if _CTX["policy"] == "dp":
        return P()          # pure DP: weights replicated everywhere
    msize = mesh.shape.get("model", 1)

    def div(dim_size) -> bool:
        return dim_size % msize == 0

    for pat, (kind,) in _RULES:
        if re.search(pat, path):
            if kind == "replicate":
                return P()
            if kind == "model_last":
                ax = ndim - 1
                if not div(shape[ax]):
                    return P()
                return P(*([None] * ax + ["model"]))
            if kind == "model_first":
                # first *matrix* dim (account for stacked leading layer axis
                # by taking dim -2 for rank>=2 weights)
                ax = ndim - 2
                if ax < 0 or not div(shape[ax]):
                    return P()
                return P(*([None] * ax + ["model", None]))
            if kind == "expert":
                ax = ndim - 3  # (..., E, a, b)
                if ax < 0 or not div(shape[ax]):
                    return P()
                return P(*([None] * ax + ["model", None, None]))
    return P()  # norms, scalars, mixing vectors: replicated


def param_specs(params, mesh: Mesh):
    """PartitionSpec pytree mirroring ``params``."""
    def one(path, leaf):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        return _spec_for(pstr, leaf.ndim, leaf.shape, mesh)
    return jax.tree_util.tree_map_with_path(one, params)


def param_shardings(params, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh))


def _zero1_augment(spec: P, shape, mesh: Mesh) -> P:
    """ZeRO-1: additionally shard optimizer state over 'data' on the first
    unsharded dim it divides."""
    if "data" not in mesh.axis_names:
        return spec
    dsize = mesh.shape["data"]
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for i, (p, s) in enumerate(zip(parts, shape)):
        if p is None and s % dsize == 0:
            parts[i] = "data"
            return P(*parts)
    return spec


def opt_specs(opt_state, params, mesh: Mesh):
    """PartitionSpecs for AdamW state: param specs + ZeRO-1 'data' sharding.

    Handles both plain f32 moments (leaf mirrors the param) and int8
    block-quantized moments ({"q","s"} dicts; sharded over 'data' on the
    block dim)."""
    pspecs = param_specs(params, mesh)
    dsize = mesh.shape.get("data", 1)

    def moment_spec(pspec, leaf):
        if isinstance(leaf, dict):  # compressed: {"q": (nb,128), "s": (nb,1)}
            def qs(x):
                return (P("data", None) if x.shape[0] % dsize == 0 else P())
            return {k: qs(v) for k, v in leaf.items()}
        return _zero1_augment(pspec, leaf.shape, mesh)

    flat_p, tdef = jax.tree.flatten(params)
    flat_ps = jax.tree.leaves(pspecs)
    m_leaves = tdef.flatten_up_to(opt_state["m"])
    v_leaves = tdef.flatten_up_to(opt_state["v"])
    m_specs = tdef.unflatten([moment_spec(ps, l)
                              for ps, l in zip(flat_ps, m_leaves)])
    v_specs = tdef.unflatten([moment_spec(ps, l)
                              for ps, l in zip(flat_ps, v_leaves)])
    return {"step": P(), "m": m_specs, "v": v_specs}


def opt_shardings(opt_state, params, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        opt_specs(opt_state, params, mesh),
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh: Mesh, global_batch: int) -> P:
    """Shard batch over as many DP axes as divide it; replicate otherwise."""
    axes = []
    prod = 1
    for a in dp_axes(mesh):
        if global_batch % (prod * mesh.shape[a]) == 0:
            axes.append(a)
            prod *= mesh.shape[a]
    if not axes:
        return P(None)
    return P(tuple(axes) if len(axes) > 1 else axes[0])


def cache_spec(mesh: Mesh, cfg, batch: int) -> dict:
    """PartitionSpecs for the decode cache (see model.init_cache layout)."""
    msize = mesh.shape.get("model", 1)
    b = batch_spec(mesh, batch)
    bax = b[0] if len(b) else None
    kv_shardable = cfg.n_kv % msize == 0
    # (L, B, S, n_kv, hd): shard kv heads over model if divisible, else the
    # sequence dim (GSPMD inserts the partial-softmax collectives).
    if kv_shardable:
        kvspec = P(None, bax, None, "model", None)
    else:
        kvspec = P(None, bax, "model", None, None)
    specs = {"pos": P()}
    if cfg.mixer == "attn":
        specs["k"] = kvspec
        specs["v"] = kvspec
    elif cfg.mixer == "rwkv6":
        specs["wkv"] = P(None, bax, "model", None, None)   # heads over model
        specs["x_att"] = P(None, bax, "model")
        specs["x_ffn"] = P(None, bax, "model")
    elif cfg.mixer == "mamba2":
        specs["ssm"] = P(None, bax, "model", None, None)
        specs["conv"] = P(None, bax, None, "model")
        if cfg.attn_every:
            specs["k"] = kvspec
            specs["v"] = kvspec
    return specs
