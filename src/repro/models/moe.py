"""Token-choice top-k MoE with capacity, sort-based dispatch, EP over 'model'.

Sharding strategy (DESIGN.md §5): activations entering the MoE block are
sharded over the DP axes and *replicated* over 'model'; expert weights are
sharded on the expert dimension over 'model'.  Tokens are first reshaped
into (G, T_loc, D) where G = number of DP shards, and the whole dispatch
(top-k, sort, capacity) is vmapped over G — every routing op is then local
to its DP shard (no cross-device sort).  Each model shard gathers the
tokens routed to ITS local experts from its local token copy, runs the
expert FFNs, scatters partial outputs, and one psum over 'model' combines
them — the collective volume of a tensor-parallel MLP, no all-to-all.

Dispatch is the static-shape sort trick: argsort expert ids -> rank within
expert -> (E, C) token-index table with capacity-overflow drop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding
from repro.models import layers


def init_moe(key, d: int, f_expert: int, n_experts: int, n_shared: int,
             act: str, dtype):
    ks = jax.random.split(key, 5)
    s_in = float(1.0 / np.sqrt(d))
    s_out = float(1.0 / np.sqrt(f_expert))
    p = {
        "router": jax.random.normal(ks[0], (d, n_experts), dtype) * s_in,
        "w_up": jax.random.normal(ks[1], (n_experts, d, f_expert), dtype) * s_in,
        "w_gate": jax.random.normal(ks[2], (n_experts, d, f_expert), dtype) * s_in,
        "w_down": jax.random.normal(ks[3], (n_experts, f_expert, d), dtype) * s_out,
    }
    if n_shared:
        p["shared"] = layers.init_mlp(ks[4], d, f_expert * n_shared, act, dtype)
    return p


def _dispatch_group(xt, router, E: int, K: int, C: int):
    """Per-DP-group dispatch. xt: (T, D) -> (token_of_slot, gate_of_slot)."""
    T = xt.shape[0]
    logits = (xt @ router).astype(jnp.float32)               # (T, E)
    gates, ids = jax.lax.top_k(logits, K)                    # (T, K)
    gates = jax.nn.softmax(gates, axis=-1)
    flat_ids = ids.reshape(-1)                               # (T*K,)
    order = jnp.argsort(flat_ids, stable=True)               # group by expert
    sorted_ids = flat_ids[order]
    counts = jnp.bincount(flat_ids, length=E)
    offsets = jnp.cumsum(counts) - counts                    # exclusive
    rank = jnp.arange(T * K) - offsets[sorted_ids]           # rank in expert
    keep = rank < C
    slot = jnp.where(keep, sorted_ids * C + rank, E * C)     # OOB drop slot
    tok = order // K
    gate_flat = gates.reshape(-1)[order]
    token_of_slot = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(
        tok.astype(jnp.int32))
    gate_of_slot = jnp.zeros((E * C + 1,), jnp.float32).at[slot].set(gate_flat)
    return token_of_slot[:-1].reshape(E, C), gate_of_slot[:-1].reshape(E, C)


def _expert_w(p, key, dtype=jnp.bfloat16):
    """Expert weight fetch with on-the-fly int8 dequantization (serving
    weight compression, §Perf hillclimb 2): quantized weights are stored as
    {"q": int8 (E,a,b), "s": f32 (E,1,b)} and expanded at use — the memory
    system reads 1 byte/weight instead of 2."""
    w = p[key]
    if isinstance(w, dict):
        return w["q"].astype(dtype) * w["s"].astype(dtype)
    return w


def quantize_expert_weights(p_moe):
    """Host/serve-time transform: per-(expert, out-channel) int8 weights."""
    out = dict(p_moe)
    for key in ("w_up", "w_gate", "w_down"):
        w = p_moe[key]
        amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-2, keepdims=True)
        s = amax / 127.0 + 1e-12
        q = jnp.clip(jnp.round(w.astype(jnp.float32) / s), -127, 127
                     ).astype(jnp.int8)
        out[key] = {"q": q, "s": s.astype(jnp.float32)}
    return out


def abstract_quantize_expert_weights(p_moe):
    """ShapeDtypeStruct version of quantize_expert_weights (dry-run)."""
    import jax as _jax
    out = dict(p_moe)
    for key in ("w_up", "w_gate", "w_down"):
        w = p_moe[key]
        s_shape = w.shape[:-2] + (1,) + w.shape[-1:]
        out[key] = {"q": _jax.ShapeDtypeStruct(w.shape, jnp.int8),
                    "s": _jax.ShapeDtypeStruct(s_shape, jnp.float32)}
    return out


def moe_ffn(p, x: jnp.ndarray, *, n_experts: int, top_k: int,
            capacity_factor: float = 1.25, act: str = "swiglu",
            decode_global: bool = True) -> jnp.ndarray:
    """x: (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    E, K = n_experts, top_k
    # Decode (S == 1): dispatch GLOBALLY (G=1).  Per-DP-group dispatch at
    # tiny token counts pads every group to >= 8 slots on EVERY expert —
    # ~100x redundant expert compute for a 128-token decode batch
    # (§Perf hillclimb 2).  The token all-gather this implies is ~1 MB.
    G = sharding.dp_groups(B) if (S > 1 or not decode_global) else 1
    T = (B * S) // G                                         # tokens per group
    xg_in = x.reshape(G, T, D)
    xg_in = sharding.constrain(xg_in, "dp" if G > 1 else None, None, None)

    C = int(np.ceil(T * K / E * capacity_factor))
    C = max(8, min(C, T))

    token_of_slot, gate_of_slot = jax.vmap(
        lambda xt: _dispatch_group(xt, p["router"], E, K, C))(xg_in)

    pad = jnp.zeros((G, 1, D), x.dtype)
    xt_pad = jnp.concatenate([xg_in, pad], axis=1)           # (G, T+1, D)
    xg = jnp.take_along_axis(
        xt_pad.reshape(G, T + 1, D),
        token_of_slot.reshape(G, E * C, 1).astype(jnp.int32), axis=1)
    xg = xg.reshape(G, E, C, D)
    if G > 1:
        xg = sharding.constrain(xg, "dp", "model", None, None)  # EP over model
    else:
        xg = sharding.constrain(xg, None, "model", None, None)
    up = jnp.einsum("gecd,edf->gecf", xg, _expert_w(p, "w_up", x.dtype))
    gate_h = jnp.einsum("gecd,edf->gecf", xg, _expert_w(p, "w_gate", x.dtype))
    h = jax.nn.silu(gate_h) * up
    y = jnp.einsum("gecf,efd->gecd", h,
                   _expert_w(p, "w_down", x.dtype))  # (G,E,C,D)
    y = y * gate_of_slot[..., None].astype(y.dtype)

    def scatter_group(tos, yg):
        return jnp.zeros((T + 1, D), y.dtype).at[tos.reshape(-1)].add(
            yg.reshape(E * C, D))[:T]

    out = jax.vmap(scatter_group)(token_of_slot, y)          # (G, T, D)
    out = sharding.constrain(out, "dp", None, None)
    if "shared" in p:
        out = out + layers.mlp(p["shared"], xg_in, act)
    return out.reshape(B, S, D).astype(x.dtype)
