# Decoder-LM model stack covering the 10 assigned architectures
# (dense / GQA / qk-norm / MoE / RWKV6 / Mamba2-hybrid / modality-stub).
