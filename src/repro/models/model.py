"""Model assembly: params init, full-seq forward, cached decode step.

One functional decoder-LM covering the 10 assigned archs:
  * dense GQA transformers (optionally qk_norm, non-param LN, relu^2/gelu)
  * MoE transformers (token-choice top-k + optional shared experts)
  * RWKV6 (attention-free: wkv mixer + token-shift channel mix)
  * Mamba2 hybrids (zamba2: SSD blocks + ONE shared attn+MLP block applied
    every `attn_every` layers, weights reused)
  * modality stubs (musicgen/paligemma): precomputed prefix embeddings are
    concatenated in front of the token embeddings (`input_specs()` supplies
    them as ShapeDtypeStructs for the dry-run).

Layers are stacked on a leading axis and driven by `lax.scan` (+ optional
remat) so the HLO stays compact for the 94-layer MoE / 61-layer 1T configs.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ArchConfig
from repro.distributed import sharding
from repro.models import attention, layers, moe, ssm

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def _init_block(cfg: ArchConfig, key) -> Dict[str, Any]:
    dt = DTYPES[cfg.dtype]
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": layers.norm_params(cfg.norm, d, dt)}
    if cfg.mixer == "attn":
        p["attn"] = attention.init_attn(k1, d, cfg.n_heads, cfg.n_kv, cfg.hd,
                                        cfg.qk_norm, dt)
        p["ln2"] = layers.norm_params(cfg.norm, d, dt)
        if cfg.is_moe:
            p["moe"] = moe.init_moe(k2, d, f, cfg.n_experts,
                                    cfg.n_shared_experts, cfg.act, dt)
        else:
            p["mlp"] = layers.init_mlp(k2, d, f, cfg.act, dt)
    elif cfg.mixer == "rwkv6":
        p["rwkv"] = ssm.init_rwkv6(k1, d, cfg.n_heads, dt)
        p["ln2"] = layers.norm_params(cfg.norm, d, dt)
        p["cmix"] = ssm.init_rwkv6_channel_mix(k2, d, f, dt)
    elif cfg.mixer == "mamba2":
        p["mamba"] = ssm.init_mamba2(k1, d, head_dim=cfg.hd,
                                     ssm_state=cfg.ssm_state, dtype=dt)
    return p


def init_params(cfg: ArchConfig, key) -> Dict[str, Any]:
    dt = DTYPES[cfg.dtype]
    d = cfg.d_model
    ke, kb, kh, ks = jax.random.split(key, 4)
    block_keys = jax.random.split(kb, cfg.n_layers)
    params: Dict[str, Any] = {
        "embed": layers.init_embed(ke, cfg.vocab, d, dt),
        "blocks": jax.vmap(lambda k: _init_block(cfg, k))(block_keys),
        "ln_f": layers.norm_params(cfg.norm, d, dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(kh, (d, cfg.vocab), dt) * float(1.0 / np.sqrt(d))
    if cfg.attn_every:  # zamba2 shared transformer block
        k1, k2 = jax.random.split(ks)
        params["shared_block"] = {
            "ln1": layers.norm_params(cfg.norm, d, dt),
            "attn": attention.init_attn(k1, d, cfg.n_heads, cfg.n_kv, cfg.hd,
                                        cfg.qk_norm, dt),
            "ln2": layers.norm_params(cfg.norm, d, dt),
            "mlp": layers.init_mlp(k2, d, cfg.d_ff, "swiglu", dt),
        }
    return params


def abstract_params(cfg: ArchConfig):
    """ShapeDtypeStruct tree — no allocation (dry-run path)."""
    return jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.key(0))


# --------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# --------------------------------------------------------------------------


def _block_fwd(cfg: ArchConfig, p, x, shared_block, layer_idx,
               unroll: bool = False):
    if cfg.mixer == "attn":
        h = layers.apply_norm(cfg.norm, x, p["ln1"])
        x = x + attention.attention(
            p["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.hd,
            qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta, unroll=unroll,
            block_skip=cfg.block_skip)
        h = layers.apply_norm(cfg.norm, x, p["ln2"])
        if cfg.is_moe:
            x = x + moe.moe_ffn(
                p["moe"], h, n_experts=cfg.n_experts, top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor, act=cfg.act)
        else:
            x = x + layers.mlp(p["mlp"], h, cfg.act)
    elif cfg.mixer == "rwkv6":
        h = layers.apply_norm(cfg.norm, x, p["ln1"])
        o, _ = ssm.rwkv6_mix(p["rwkv"], h, n_heads=cfg.n_heads)
        x = x + o
        h = layers.apply_norm(cfg.norm, x, p["ln2"])
        x = x + ssm.rwkv6_channel_mix(p["cmix"], h)
    elif cfg.mixer == "mamba2":
        h = layers.apply_norm(cfg.norm, x, p["ln1"])
        o, _ = ssm.mamba2_mix(p["mamba"], h, head_dim=cfg.hd,
                              ssm_state=cfg.ssm_state, ssd_chunk=cfg.ssd_chunk,
                              unroll=unroll)
        x = x + o
        if cfg.attn_every:
            def apply_shared(x):
                sb = shared_block
                h = layers.apply_norm(cfg.norm, x, sb["ln1"])
                x = x + attention.attention(
                    sb["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
                    head_dim=cfg.hd, rope_theta=cfg.rope_theta, unroll=unroll,
                    block_skip=cfg.block_skip)
                h = layers.apply_norm(cfg.norm, x, sb["ln2"])
                return x + layers.mlp(sb["mlp"], h, "swiglu")
            if isinstance(layer_idx, int):      # python-unrolled layer loop
                if (layer_idx + 1) % cfg.attn_every == 0:
                    x = apply_shared(x)
            else:
                x = lax.cond((layer_idx + 1) % cfg.attn_every == 0,
                             apply_shared, lambda x: x, x)
    return x


def _layer_stack(cfg: ArchConfig, params, x, remat: bool, unroll: bool):
    """Apply all blocks: lax.scan over stacked params, or a python loop
    (unroll=True — exact HLO cost accounting for the dry-run probes)."""
    shared = params.get("shared_block")
    if unroll:
        body = _block_fwd
        if remat:
            body = jax.checkpoint(_block_fwd, static_argnums=(0, 4, 5))
        for i in range(cfg.n_layers):
            p_i = jax.tree.map(lambda a: a[i], params["blocks"])
            x = body(cfg, p_i, x, shared, i, True)
            x = sharding.constrain(x, "dp", None, None)
        return x

    def body(x, scanned):
        p, idx = scanned
        x = _block_fwd(cfg, p, x, shared, idx)
        x = sharding.constrain(x, "dp", None, None)
        return x, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = lax.scan(body, x, (params["blocks"],
                              jnp.arange(cfg.n_layers, dtype=jnp.int32)))
    return x


def embed_inputs(cfg: ArchConfig, params, tokens, prefix_emb=None):
    x = jnp.take(params["embed"], tokens, axis=0)
    x = sharding.constrain(x, "dp", None, None)
    if cfg.n_prefix and prefix_emb is not None:
        x = jnp.concatenate([prefix_emb.astype(x.dtype), x], axis=1)
    return x


def forward(cfg: ArchConfig, params, tokens: jnp.ndarray,
            prefix_emb: Optional[jnp.ndarray] = None,
            remat: bool = False, unroll: bool = False) -> jnp.ndarray:
    """tokens: (B, S) int32 -> logits (B, S(+prefix), vocab)."""
    x = embed_inputs(cfg, params, tokens, prefix_emb)
    x = _layer_stack(cfg, params, x, remat, unroll)
    x = layers.apply_norm(cfg.norm, x, params["ln_f"])
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return x @ head


def loss_fn(cfg: ArchConfig, params, tokens, labels, prefix_emb=None,
            remat: bool = True, seq_chunk: int = 512, unroll: bool = False):
    """Next-token cross entropy, computed over sequence chunks so the f32
    (B, S, vocab) softmax intermediate never materializes whole."""
    x = embed_inputs(cfg, params, tokens, prefix_emb)
    x = _layer_stack(cfg, params, x, remat, unroll)
    x = layers.apply_norm(cfg.norm, x, params["ln_f"])
    if cfg.n_prefix:
        x = x[:, cfg.n_prefix:]
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])

    B, S, D = x.shape
    n_chunks = max(1, S // seq_chunk)
    xs = x.reshape(B, n_chunks, S // n_chunks, D).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n_chunks, S // n_chunks).transpose(1, 0, 2)

    def chunk_loss(carry, inp):
        xc, lc = inp
        logits = (xc @ head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(logz - gold), None

    if unroll:
        total = jnp.float32(0.0)
        for i in range(n_chunks):
            total, _ = chunk_loss(total, (xs[i], ls[i]))
    else:
        total, _ = lax.scan(chunk_loss, jnp.float32(0.0), (xs, ls))
    return total / (B * S)


# --------------------------------------------------------------------------
# cached decode
# --------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               abstract: bool = False) -> Dict[str, Any]:
    """KV / recurrent-state cache. Shapes only if abstract=True."""
    dt = DTYPES[cfg.dtype]
    L, d = cfg.n_layers, cfg.d_model
    mk = (jax.ShapeDtypeStruct if abstract
          else lambda s, t: jnp.zeros(s, t))
    cache: Dict[str, Any] = {"pos": (jax.ShapeDtypeStruct((), jnp.int32)
                                     if abstract else jnp.int32(0))}
    if cfg.mixer == "attn":
        cache["k"] = mk((L, batch, max_seq, cfg.n_kv, cfg.hd), dt)
        cache["v"] = mk((L, batch, max_seq, cfg.n_kv, cfg.hd), dt)
    elif cfg.mixer == "rwkv6":
        H, hd = cfg.n_heads, d // cfg.n_heads
        cache["wkv"] = mk((L, batch, H, hd, hd), jnp.float32)
        cache["x_att"] = mk((L, batch, d), dt)
        cache["x_ffn"] = mk((L, batch, d), dt)
    elif cfg.mixer == "mamba2":
        di = 2 * d
        H = di // cfg.hd
        cache["ssm"] = mk((L, batch, H, cfg.hd, cfg.ssm_state), jnp.float32)
        cache["conv"] = mk((L, batch, ssm.CONV_K - 1, di), dt)
        if cfg.attn_every:
            n_apps = cfg.n_layers // cfg.attn_every
            cache["k"] = mk((n_apps, batch, max_seq, cfg.n_kv, cfg.hd), dt)
            cache["v"] = mk((n_apps, batch, max_seq, cfg.n_kv, cfg.hd), dt)
    return cache


def decode_step(cfg: ArchConfig, params, cache: Dict[str, Any],
                tokens: jnp.ndarray,
                unroll: bool = False) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One decode step. tokens: (B, 1) -> (logits (B, 1, vocab), cache).

    unroll=True: python layer loop (exact dry-run probe accounting)."""
    if unroll:
        return _decode_step_unrolled(cfg, params, cache, tokens)
    pos = cache["pos"]
    x = jnp.take(params["embed"], tokens, axis=0)
    x = sharding.constrain(x, "dp", None, None)
    shared = params.get("shared_block")
    L = cfg.n_layers

    if cfg.mixer == "attn":
        def body(carry, scanned):
            x = carry
            p, ck, cv = scanned
            h = layers.apply_norm(cfg.norm, x, p["ln1"])
            o, ck, cv = attention.decode_attention(
                p["attn"], h, ck, cv, pos, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv, head_dim=cfg.hd, qk_norm=cfg.qk_norm,
                rope_theta=cfg.rope_theta)
            x = x + o
            h = layers.apply_norm(cfg.norm, x, p["ln2"])
            if cfg.is_moe:
                x = x + moe.moe_ffn(p["moe"], h, n_experts=cfg.n_experts,
                                    top_k=cfg.top_k,
                                    capacity_factor=cfg.capacity_factor,
                                    act=cfg.act,
                                    decode_global=cfg.moe_decode_global)
            else:
                x = x + layers.mlp(p["mlp"], h, cfg.act)
            return x, (ck, cv)

        x, (ks, vs) = lax.scan(body, x, (params["blocks"], cache["k"], cache["v"]))
        cache = dict(cache, k=ks, v=vs)

    elif cfg.mixer == "rwkv6":
        def body(carry, scanned):
            x = carry
            p, wkv, xa, xf = scanned
            h = layers.apply_norm(cfg.norm, x, p["ln1"])
            o, (wkv, xa_new) = ssm.rwkv6_mix(p["rwkv"], h,
                                             n_heads=cfg.n_heads,
                                             state=(wkv, xa))
            x = x + o
            h = layers.apply_norm(cfg.norm, x, p["ln2"])
            o, xf_new = ssm.rwkv6_channel_mix(p["cmix"], h, x_last=xf)
            x = x + o
            return x, (wkv, xa_new, xf_new)

        x, (wkvs, xas, xfs) = lax.scan(
            body, x, (params["blocks"], cache["wkv"], cache["x_att"],
                      cache["x_ffn"]))
        cache = dict(cache, wkv=wkvs, x_att=xas, x_ffn=xfs)

    elif cfg.mixer == "mamba2":
        n_apps = max(1, cfg.n_layers // cfg.attn_every) if cfg.attn_every else 0

        def body(carry, scanned):
            x, ak, av = carry
            p, hst, cst, idx = scanned
            h = layers.apply_norm(cfg.norm, x, p["ln1"])
            o, (hst, cst) = ssm.mamba2_mix(p["mamba"], h, head_dim=cfg.hd,
                                           ssm_state=cfg.ssm_state,
                                           state=(hst, cst))
            x = x + o
            if cfg.attn_every:
                app = idx // cfg.attn_every

                def apply_shared(args):
                    x, ak, av = args
                    sb = shared
                    h = layers.apply_norm(cfg.norm, x, sb["ln1"])
                    ck = lax.dynamic_index_in_dim(ak, app, 0, keepdims=False)
                    cv = lax.dynamic_index_in_dim(av, app, 0, keepdims=False)
                    o, ck, cv = attention.decode_attention(
                        sb["attn"], h, ck, cv, pos, n_heads=cfg.n_heads,
                        n_kv=cfg.n_kv, head_dim=cfg.hd,
                        rope_theta=cfg.rope_theta)
                    x = x + o
                    h = layers.apply_norm(cfg.norm, x, sb["ln2"])
                    x = x + layers.mlp(sb["mlp"], h, "swiglu")
                    ak = lax.dynamic_update_index_in_dim(ak, ck, app, 0)
                    av = lax.dynamic_update_index_in_dim(av, cv, app, 0)
                    return x, ak, av

                x, ak, av = lax.cond((idx + 1) % cfg.attn_every == 0,
                                     apply_shared, lambda a: a, (x, ak, av))
            return (x, ak, av), (hst, cst)

        ak0 = cache.get("k", jnp.zeros((1, 1, 1, 1, 1), DTYPES[cfg.dtype]))
        av0 = cache.get("v", jnp.zeros((1, 1, 1, 1, 1), DTYPES[cfg.dtype]))
        (x, ak, av), (hsts, csts) = lax.scan(
            body, (x, ak0, av0),
            (params["blocks"], cache["ssm"], cache["conv"],
             jnp.arange(L, dtype=jnp.int32)))
        cache = dict(cache, ssm=hsts, conv=csts)
        if cfg.attn_every:
            cache = dict(cache, k=ak, v=av)

    x = layers.apply_norm(cfg.norm, x, params["ln_f"])
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x @ head
    cache = dict(cache, pos=pos + 1)
    return logits, cache


def _decode_step_unrolled(cfg: ArchConfig, params, cache, tokens):
    """Python-layer-loop decode (dry-run probe path; numerics identical)."""
    pos = cache["pos"]
    x = jnp.take(params["embed"], tokens, axis=0)
    x = sharding.constrain(x, "dp", None, None)
    shared = params.get("shared_block")
    new_cache = dict(cache)

    for i in range(cfg.n_layers):
        p = jax.tree.map(lambda a: a[i], params["blocks"])
        h = layers.apply_norm(cfg.norm, x, p["ln1"])
        if cfg.mixer == "attn":
            o, ck, cv = attention.decode_attention(
                p["attn"], h, new_cache["k"][i], new_cache["v"][i], pos,
                n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.hd,
                qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta)
            new_cache["k"] = new_cache["k"].at[i].set(ck)
            new_cache["v"] = new_cache["v"].at[i].set(cv)
            x = x + o
            h = layers.apply_norm(cfg.norm, x, p["ln2"])
            if cfg.is_moe:
                x = x + moe.moe_ffn(p["moe"], h, n_experts=cfg.n_experts,
                                    top_k=cfg.top_k,
                                    capacity_factor=cfg.capacity_factor,
                                    act=cfg.act,
                                    decode_global=cfg.moe_decode_global)
            else:
                x = x + layers.mlp(p["mlp"], h, cfg.act)
        elif cfg.mixer == "rwkv6":
            o, (wkv, xa) = ssm.rwkv6_mix(
                p["rwkv"], h, n_heads=cfg.n_heads,
                state=(new_cache["wkv"][i], new_cache["x_att"][i]))
            new_cache["wkv"] = new_cache["wkv"].at[i].set(wkv)
            new_cache["x_att"] = new_cache["x_att"].at[i].set(xa)
            x = x + o
            h = layers.apply_norm(cfg.norm, x, p["ln2"])
            o, xf = ssm.rwkv6_channel_mix(p["cmix"], h,
                                          x_last=new_cache["x_ffn"][i])
            new_cache["x_ffn"] = new_cache["x_ffn"].at[i].set(xf)
            x = x + o
        elif cfg.mixer == "mamba2":
            o, (hst, cst) = ssm.mamba2_mix(
                p["mamba"], h, head_dim=cfg.hd, ssm_state=cfg.ssm_state,
                state=(new_cache["ssm"][i], new_cache["conv"][i]))
            new_cache["ssm"] = new_cache["ssm"].at[i].set(hst)
            new_cache["conv"] = new_cache["conv"].at[i].set(cst)
            x = x + o
            if cfg.attn_every and (i + 1) % cfg.attn_every == 0:
                app = i // cfg.attn_every
                sb = shared
                h = layers.apply_norm(cfg.norm, x, sb["ln1"])
                o, ck, cv = attention.decode_attention(
                    sb["attn"], h, new_cache["k"][app], new_cache["v"][app],
                    pos, n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.hd,
                    rope_theta=cfg.rope_theta)
                new_cache["k"] = new_cache["k"].at[app].set(ck)
                new_cache["v"] = new_cache["v"].at[app].set(cv)
                x = x + o
                h = layers.apply_norm(cfg.norm, x, sb["ln2"])
                x = x + layers.mlp(sb["mlp"], h, "swiglu")

    x = layers.apply_norm(cfg.norm, x, params["ln_f"])
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x @ head
    new_cache["pos"] = pos + 1
    return logits, new_cache
