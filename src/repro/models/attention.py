"""GQA attention: flash-style chunked causal for train/prefill, cached decode.

Memory-safe full-sequence attention: online-softmax scan over KV chunks so
the (S, S) score matrix is never materialized — the (B, H, Sq, KV_CHUNK)
partial is the largest intermediate.  Causal block skipping (computing only
KV chunks <= the diagonal) is applied per Q chunk via masking of whole
chunks; see EXPERIMENTS.md §Perf for the block-skip optimization history.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models import layers

KV_CHUNK = 1024
Q_CHUNK = 2048

NEG_INF = -1e30


def _divisor_chunk(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (handles prefix-extended
    sequence lengths like 32768+256 that aren't powers of two)."""
    d = min(n, target)
    while n % d:
        d -= 1
    return d


def init_attn(key, d: int, n_heads: int, n_kv: int, head_dim: int,
              qk_norm: bool, dtype):
    kq, kk, kv, ko, kn = jax.random.split(key, 5)
    s = float(1.0 / np.sqrt(d))
    p = {
        "wq": jax.random.normal(kq, (d, n_heads * head_dim), dtype) * s,
        "wk": jax.random.normal(kk, (d, n_kv * head_dim), dtype) * s,
        "wv": jax.random.normal(kv, (d, n_kv * head_dim), dtype) * s,
        "wo": jax.random.normal(ko, (n_heads * head_dim, d), dtype)
              * float(1.0 / np.sqrt(n_heads * head_dim)),
    }
    if qk_norm:
        p["q_norm"] = jnp.ones((head_dim,), dtype)
        p["k_norm"] = jnp.ones((head_dim,), dtype)
    return p


def _project_qkv(p, x, n_heads, n_kv, head_dim, qk_norm, positions, rope_theta):
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, n_heads, head_dim)
    k = (x @ p["wk"]).reshape(B, S, n_kv, head_dim)
    v = (x @ p["wv"]).reshape(B, S, n_kv, head_dim)
    if qk_norm:
        q = layers.rmsnorm(q, p["q_norm"])
        k = layers.rmsnorm(k, p["k_norm"])
    q = layers.apply_rope(q, positions, rope_theta)
    k = layers.apply_rope(k, positions, rope_theta)
    return q, k, v


def _attend_chunk(carry, q32, kci, vci, kv_pos, q_pos, causal):
    """One (q-chunk, kv-chunk) online-softmax update."""
    m, l, acc = carry
    s = jnp.einsum("bhqd,bhkd->bhqk", q32, kci.astype(jnp.float32))
    if causal:
        mask = q_pos[:, None] >= kv_pos[None, :]
        s = jnp.where(mask[None, None], s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p_ = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p_, axis=-1)
    acc_new = acc * corr[..., None] + jnp.einsum(
        "bhqk,bhkd->bhqd", p_, vci.astype(jnp.float32))
    return m_new, l_new, acc_new


def _flash_qchunk(q, k, v, q_start, causal: bool, block_skip: bool,
                  unroll: bool):
    """Online-softmax over KV chunks for one Q chunk.

    q: (B, H, Sq, hd); k/v: (B, H, Skv, hd) (already GQA-expanded).
    q_start: absolute int position of q[0] (for causal masking).

    block_skip (beyond-paper perf, see EXPERIMENTS.md §Perf): with
    unroll=True, KV chunks strictly in the future of this Q chunk are not
    even lowered — real triangular FLOP saving with static shapes (only
    possible because the chunk loop is a python loop).
    """
    B, H, Sq, hd = q.shape
    Skv = k.shape[2]
    kv_chunk = _divisor_chunk(Skv, KV_CHUNK)
    n_kv_chunks = Skv // kv_chunk
    scale = 1.0 / np.sqrt(hd)
    kc = k.reshape(B, H, n_kv_chunks, kv_chunk, hd)
    vc = v.reshape(B, H, n_kv_chunks, kv_chunk, hd)
    q32 = q.astype(jnp.float32) * scale
    q_pos = q_start + jnp.arange(Sq)

    init = (jnp.full((B, H, Sq), NEG_INF, jnp.float32),
            jnp.zeros((B, H, Sq), jnp.float32),
            jnp.zeros((B, H, Sq, hd), jnp.float32))

    if unroll:
        n_live = n_kv_chunks
        if causal and block_skip:
            # chunks fully in the future contribute nothing: drop them
            n_live = min(n_kv_chunks, (q_start + Sq - 1) // kv_chunk + 1)
        carry = init
        for ci in range(n_live):
            kv_pos = ci * kv_chunk + jnp.arange(kv_chunk)
            carry = _attend_chunk(carry, q32, kc[:, :, ci], vc[:, :, ci],
                                  kv_pos, q_pos, causal)
        m, l, acc = carry
    else:
        def step(carry, inputs):
            kci, vci, c_idx = inputs
            kv_pos = c_idx * kv_chunk + jnp.arange(kv_chunk)
            new = _attend_chunk(carry, q32, kci, vci, kv_pos, q_pos, causal)
            if causal and block_skip:
                live = (c_idx * kv_chunk) <= (q_start + Sq - 1)
                new = jax.tree.map(lambda a, b: jnp.where(live, a, b),
                                   new, carry)
            return new, None

        carry, _ = lax.scan(
            step, init,
            (kc.transpose(2, 0, 1, 3, 4), vc.transpose(2, 0, 1, 3, 4),
             jnp.arange(n_kv_chunks)))
        m, l, acc = carry
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)


def attention(p, x: jnp.ndarray, *, n_heads: int, n_kv: int, head_dim: int,
              qk_norm: bool = False, rope_theta: float = 10000.0,
              causal: bool = True, block_skip: bool = True,
              unroll: bool = False) -> jnp.ndarray:
    """Full-sequence attention (training / prefill). x: (B, S, D).

    unroll=True replaces the chunk scans with python loops: exact HLO cost
    accounting for the dry-run probes AND enables true triangular skipping.
    """
    B, S, D = x.shape
    positions = jnp.arange(S)[None, :].astype(jnp.int32)
    q, k, v = _project_qkv(p, x, n_heads, n_kv, head_dim, qk_norm,
                           positions, rope_theta)
    rep = n_heads // n_kv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    q = q.transpose(0, 2, 1, 3)   # (B, H, S, hd)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    if S <= Q_CHUNK:
        o = _flash_qchunk(q, k, v, 0, causal, block_skip, unroll)
    else:
        q_chunk = _divisor_chunk(S, Q_CHUNK)
        nq = S // q_chunk
        if unroll:
            outs = []
            for i in range(nq):
                qc = q[:, :, i * q_chunk:(i + 1) * q_chunk]
                outs.append(_flash_qchunk(qc, k, v, i * q_chunk, causal,
                                          block_skip, True))
            o = jnp.concatenate(outs, axis=2)
        else:
            qs = q.reshape(B, n_heads, nq, q_chunk, head_dim).transpose(
                2, 0, 1, 3, 4)

            def one(t):
                qc, idx = t
                return _flash_qchunk(qc, k, v, idx * q_chunk, causal,
                                     block_skip, False)

            o = lax.map(one, (qs, jnp.arange(nq)))
            o = o.transpose(1, 2, 0, 3, 4).reshape(B, n_heads, S, head_dim)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, n_heads * head_dim)
    return o @ p["wo"]


def decode_attention(p, x: jnp.ndarray, cache_k: jnp.ndarray,
                     cache_v: jnp.ndarray, pos, *, n_heads: int,
                     n_kv: int, head_dim: int, qk_norm: bool = False,
                     rope_theta: float = 10000.0):
    """Single-token decode. x: (B, 1, D); cache: (B, Smax, n_kv, hd).

    Returns (out (B,1,D), new_cache_k, new_cache_v).
    """
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _project_qkv(p, x, n_heads, n_kv, head_dim, qk_norm,
                           positions, rope_theta)
    cache_k = lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                       (0, pos, 0, 0))
    cache_v = lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                       (0, pos, 0, 0))
    Smax = cache_k.shape[1]
    rep = n_heads // n_kv
    # scores against the full cache, masked beyond pos
    q_ = q.reshape(B, n_kv, rep, head_dim)                     # (B, kv, rep, hd)
    s = jnp.einsum("bkrd,bskd->bkrs", q_.astype(jnp.float32),
                   cache_k.astype(jnp.float32)) / np.sqrt(head_dim)
    mask = (jnp.arange(Smax) <= pos)[None, None, None, :]
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkrs,bskd->bkrd", w, cache_v.astype(jnp.float32))
    o = o.reshape(B, 1, n_heads * head_dim).astype(x.dtype)
    return o @ p["wo"], cache_k, cache_v
