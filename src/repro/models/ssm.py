"""Recurrent token mixers: RWKV6 (Finch) and Mamba2 (SSD), + decode steps.

RWKV6 (data-dependent decay, arXiv:2404.05892), per head h with K=V=head_dim:
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
    w_t = exp(-exp(w_base + lora(x_t)))     (the data-dependent decay)
plus token-shift interpolation on the inputs.

Mamba2 / SSD (arXiv:2405.21060), per head with state N = ssm_state:
    h_t = a_t h_{t-1} + dt_t * (x_t ⊗ B_t)
    y_t = h_t C_t + D x_t,   a_t = exp(-dt_t * exp(A_log))
with a short causal conv on the input path and SiLU gating (z branch).

Both are implemented as chunked `lax.scan` over time (exact recurrence;
the chunkwise-parallel form is a §Perf optimization), O(1) state for decode
— which is why rwkv6/zamba2 are the two archs that run `long_500k`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


# --------------------------------------------------------------------------
# RWKV6
# --------------------------------------------------------------------------


def init_rwkv6(key, d: int, n_heads: int, dtype):
    hd = d // n_heads
    ks = jax.random.split(key, 8)
    s = float(1.0 / np.sqrt(d))
    return {
        "w_r": jax.random.normal(ks[0], (d, d), dtype) * s,
        "w_k": jax.random.normal(ks[1], (d, d), dtype) * s,
        "w_v": jax.random.normal(ks[2], (d, d), dtype) * s,
        "w_g": jax.random.normal(ks[3], (d, d), dtype) * s,
        "w_o": jax.random.normal(ks[4], (d, d), dtype) * s,
        "w_decay": jax.random.normal(ks[5], (d, d), dtype) * s * 0.1,
        "decay_base": jnp.zeros((d,), dtype),
        "bonus_u": jnp.zeros((n_heads, hd), dtype),
        "mix": jax.random.uniform(ks[6], (5, d), dtype),  # token-shift lerps
    }


def _token_shift(x, x_prev_last=None):
    """shift x right by one step; x: (B, S, D). x_prev_last: (B, D) or None."""
    if x_prev_last is None:
        pad = jnp.zeros_like(x[:, :1])
    else:
        pad = x_prev_last[:, None]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def rwkv6_mix(p, x: jnp.ndarray, *, n_heads: int, state=None):
    """x: (B, S, D). state: optional (S_wkv (B,H,K,V), x_last (B,D)).

    Returns (out (B,S,D), new_state)."""
    B, S, D = x.shape
    H = n_heads
    hd = D // H
    x_last = None if state is None else state[1]
    xs = _token_shift(x, x_last)
    mix = p["mix"]

    def lerp(i):
        return x + (xs - x) * mix[i]

    r = (lerp(0) @ p["w_r"]).reshape(B, S, H, hd)
    k = (lerp(1) @ p["w_k"]).reshape(B, S, H, hd)
    v = (lerp(2) @ p["w_v"]).reshape(B, S, H, hd)
    g = jax.nn.silu(lerp(3) @ p["w_g"])
    decay = (p["decay_base"] + lerp(4) @ p["w_decay"]).reshape(B, S, H, hd)
    w = jnp.exp(-jnp.exp(decay.astype(jnp.float32)))        # (B,S,H,K) in (0,1)
    u = p["bonus_u"].astype(jnp.float32)

    S0 = (jnp.zeros((B, H, hd, hd), jnp.float32) if state is None
          else state[0])

    def step(Scur, inp):
        r_t, k_t, v_t, w_t = inp                            # (B,H,hd) each
        kv = k_t[..., :, None] * v_t[..., None, :]          # (B,H,K,V)
        o_t = jnp.einsum("bhk,bhkv->bhv", r_t,
                         Scur + u[None, :, :, None] * kv)
        Snew = w_t[..., :, None] * Scur + kv
        return Snew, o_t

    seq = (r.transpose(1, 0, 2, 3).astype(jnp.float32),
           k.transpose(1, 0, 2, 3).astype(jnp.float32),
           v.transpose(1, 0, 2, 3).astype(jnp.float32),
           w.transpose(1, 0, 2, 3))
    S_fin, o = lax.scan(step, S0, seq)                      # o: (S,B,H,V)
    o = o.transpose(1, 0, 2, 3).reshape(B, S, D).astype(x.dtype)
    out = (o * g) @ p["w_o"]
    return out, (S_fin, x[:, -1])


def init_rwkv6_channel_mix(key, d: int, f: int, dtype):
    ks = jax.random.split(key, 3)
    s = float(1.0 / np.sqrt(d))
    return {
        "w_ck": jax.random.normal(ks[0], (d, f), dtype) * s,
        "w_cv": jax.random.normal(ks[1], (f, d), dtype) * float(1.0 / np.sqrt(f)),
        "w_cr": jax.random.normal(ks[2], (d, d), dtype) * s,
        "mix2": jax.random.uniform(ks[2], (2, d), dtype),
    }


def rwkv6_channel_mix(p, x: jnp.ndarray, x_last=None):
    """RWKV channel mix: r ⊙ (W_v · relu(W_k · lerp_k)^2), with token-shift.

    Returns out (and new x_last when called with state, for decode)."""
    xs = _token_shift(x, x_last)
    xk = x + (xs - x) * p["mix2"][0]
    xr = x + (xs - x) * p["mix2"][1]
    k = jnp.square(jax.nn.relu(xk @ p["w_ck"]))
    out = jax.nn.sigmoid(xr @ p["w_cr"]) * (k @ p["w_cv"])
    if x_last is None:
        return out
    return out, x[:, -1]


# --------------------------------------------------------------------------
# Mamba2 (SSD)
# --------------------------------------------------------------------------

CONV_K = 4


def init_mamba2(key, d: int, *, head_dim: int = 64, ssm_state: int = 64,
                expand: int = 2, dtype=jnp.bfloat16):
    di = d * expand
    H = di // head_dim
    N = ssm_state
    ks = jax.random.split(key, 6)
    s = float(1.0 / np.sqrt(d))
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di + 2 * N + H), dtype) * s,
        "conv_w": jax.random.normal(ks[1], (CONV_K, di), dtype) * 0.5,
        "A_log": jnp.zeros((H,), dtype),
        "D": jnp.ones((H,), dtype),
        "dt_bias": jnp.zeros((H,), dtype),
        "out_proj": jax.random.normal(ks[2], (di, d), dtype) * float(1.0 / np.sqrt(di)),
        "norm_z": jnp.ones((di,), dtype),
    }


def _causal_conv(x, w, conv_state=None):
    """depthwise causal conv, x: (B,S,C), w: (K,C). state: (B,K-1,C)."""
    B, S, C = x.shape
    if conv_state is None:
        pad = jnp.zeros((B, CONV_K - 1, C), x.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, x], axis=1)                   # (B, S+K-1, C)
    out = sum(xp[:, i:i + S] * w[i] for i in range(CONV_K))
    return out, xp[:, -(CONV_K - 1):]


def _ssd_chunked(xin, a, Bv, Cv, dt, h0, chunk: int, unroll: bool = False):
    """Chunkwise-parallel SSD (Mamba2 paper §6): identical recurrence, but
    states touch memory once per CHUNK instead of once per step, and the
    within-chunk work becomes MXU matmuls.  §Perf hillclimb 3.

    xin: (B,S,H,P); a,dt: (B,S,H); Bv,Cv: (B,S,N); h0: (B,H,P,N) f32.
    Returns (y (B,S,H,P) f32, h_fin).
    """
    B, S, H, P = xin.shape
    N = Bv.shape[-1]
    c = min(chunk, S)
    while S % c:
        c -= 1
    nc = S // c
    u = (dt[..., None] * xin.astype(jnp.float32)).reshape(B, nc, c, H, P)
    la = jnp.log(jnp.maximum(a, 1e-30)).reshape(B, nc, c, H)
    cum = jnp.cumsum(la, axis=2)                         # (B,nc,c,H)
    Bc = Bv.reshape(B, nc, c, N)
    Cc = Cv.reshape(B, nc, c, N)

    # within-chunk: y_t += sum_{s<=t} exp(cum_t - cum_s) (C_t.B_s) u_s
    scores = jnp.einsum("bktn,bksn->bkts", Cc, Bc)       # head-independent
    ldiff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,t,s,H)
    mask = jnp.tril(jnp.ones((c, c), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(ldiff), 0.0)
    y_intra = jnp.einsum("bkts,bktsh,bkshp->bkthp", scores, L, u)

    # cross-chunk: carried state contributes C_t exp(cum_t) h_in;
    # chunk state update: h_out = exp(cum_last) h_in + sum_s exp(cum_last -
    # cum_s) u_s B_s   — ONE state read/write per chunk.
    dec_out = jnp.exp(cum[:, :, -1:, :] - cum)           # (B,nc,c,H)
    uB = jnp.einsum("bksh,bkshp,bksn->bkhpn", dec_out, u, Bc)
    a_tot = jnp.exp(cum[:, :, -1])                       # (B,nc,H)

    def chunk_step(h, inp):
        uB_k, a_k, cum_k, C_k = inp
        y_cross = jnp.einsum("btn,bhpn,bth->bthp",
                             C_k, h, jnp.exp(cum_k))
        h = a_k[:, :, None, None] * h + uB_k
        return h, y_cross

    seq = (uB.transpose(1, 0, 2, 3, 4), a_tot.transpose(1, 0, 2),
           cum.transpose(1, 0, 2, 3), Cc.transpose(1, 0, 2, 3))
    if unroll:   # python chunk loop: exact HLO cost accounting (probes)
        h = h0
        ys = []
        for k in range(nc):
            h, y_k = chunk_step(h, jax.tree.map(lambda t: t[k], seq))
            ys.append(y_k)
        h_fin = h
        y_cross = jnp.stack(ys, axis=1)                  # (B,nc,c,H,P)
        y = y_intra + y_cross
    else:
        h_fin, y_cross = lax.scan(chunk_step, h0, seq)   # (nc,B,c,H,P)
        y = y_intra + y_cross.transpose(1, 0, 2, 3, 4)
    return y.reshape(B, S, H, P), h_fin


def mamba2_mix(p, x: jnp.ndarray, *, head_dim: int = 64, ssm_state: int = 64,
               expand: int = 2, state=None, ssd_chunk: int = 0,
               unroll: bool = False):
    """x: (B,S,D). state: (ssm (B,H,P,N) f32, conv (B,K-1,di)). -> (out, state)

    ssd_chunk > 0 selects the chunkwise-parallel SSD path (matmul-form,
    state memory traffic /chunk instead of /step)."""
    B, S, D = x.shape
    di = D * expand
    H = di // head_dim
    P, N = head_dim, ssm_state
    proj = x @ p["in_proj"]                                  # (B,S,2di+2N+H)
    z, xin, Bmat, Cmat, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    conv_state = None if state is None else state[1]
    xin, conv_new = _causal_conv(xin, p["conv_w"], conv_state)
    xin = jax.nn.silu(xin).reshape(B, S, H, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = jnp.exp(-dt * jnp.exp(p["A_log"].astype(jnp.float32)))  # (B,S,H)
    Bv = Bmat.astype(jnp.float32)                            # (B,S,N) shared heads
    Cv = Cmat.astype(jnp.float32)

    h0 = (jnp.zeros((B, H, P, N), jnp.float32) if state is None else state[0])

    if ssd_chunk and S > 1:
        y, h_fin = _ssd_chunked(xin, a, Bv, Cv, dt, h0, ssd_chunk, unroll)
    else:
        def step(h, inp):
            x_t, a_t, B_t, C_t, dt_t = inp
            upd = (dt_t[..., None, None] * x_t.astype(jnp.float32)[..., :, None]
                   * B_t[:, None, None, :])                  # (B,H,P,N)
            h = a_t[..., None, None] * h + upd
            y = jnp.einsum("bhpn,bn->bhp", h, C_t)
            return h, y

        seq = (xin.transpose(1, 0, 2, 3), a.transpose(1, 0, 2),
               Bv.transpose(1, 0, 2), Cv.transpose(1, 0, 2),
               dt.transpose(1, 0, 2))
        h_fin, y = lax.scan(step, h0, seq)                   # y: (S,B,H,P)
        y = y.transpose(1, 0, 2, 3)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xin.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    from repro.models.layers import rmsnorm
    y = rmsnorm(y, p["norm_z"])
    return y @ p["out_proj"], (h_fin, conv_new)
