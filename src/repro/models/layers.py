"""Shared layers: norms, rotary, MLPs, embeddings (pure functional JAX)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# Activation registry -------------------------------------------------------


def act_fn(name: str):
    return {
        "gelu": jax.nn.gelu,
        "silu": jax.nn.silu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),
    }[name]


# Norms ----------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def ln_nonparam(x: jnp.ndarray, _unused=None, eps: float = 1e-5) -> jnp.ndarray:
    """OLMo's non-parametric LayerNorm (no scale/bias)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps)).astype(dt)


def norm_fn(kind: str):
    return {"rmsnorm": rmsnorm, "ln_nonparam": ln_nonparam}[kind]


def norm_params(kind: str, d: int, dtype) -> jnp.ndarray | None:
    if kind == "rmsnorm":
        return jnp.ones((d,), dtype)
    return jnp.zeros((0,), dtype)  # non-parametric: placeholder leaf


def apply_norm(kind: str, x, p):
    if kind == "rmsnorm":
        return rmsnorm(x, p)
    return ln_nonparam(x)


# Rotary ---------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# MLPs -----------------------------------------------------------------------


def init_mlp(key, d: int, f: int, act: str, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = float(1.0 / np.sqrt(d))
    s_out = float(1.0 / np.sqrt(f))
    p = {"w_up": jax.random.normal(k1, (d, f), dtype) * s_in,
         "w_down": jax.random.normal(k2, (f, d), dtype) * s_out}
    if act == "swiglu":
        p["w_gate"] = jax.random.normal(k3, (d, f), dtype) * s_in
    return p


def mlp(p, x: jnp.ndarray, act: str) -> jnp.ndarray:
    up = x @ p["w_up"]
    if act == "swiglu":
        up = jax.nn.silu(x @ p["w_gate"]) * up
    else:
        up = act_fn(act)(up)
    return up @ p["w_down"]


# Embedding ------------------------------------------------------------------


def init_embed(key, vocab: int, d: int, dtype):
    return jax.random.normal(key, (vocab, d), dtype) * 0.02
