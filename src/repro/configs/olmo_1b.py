"""olmo-1b — non-parametric LN, tied embeddings [arXiv:2402.00838; hf]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv=16, d_ff=8192, vocab=50304,
    norm="ln_nonparam", tie_embeddings=True, source="arXiv:2402.00838",
))
