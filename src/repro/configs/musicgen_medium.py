"""musicgen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

Backbone only: the EnCodec frontend is a STUB — input_specs() feeds
precomputed frame embeddings as a prefix (n_prefix frames) alongside the
token stream over the 2048-entry codebook vocabulary.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv=24, d_ff=6144, vocab=2048,
    act="gelu", n_prefix=64, source="arXiv:2306.05284",
))
