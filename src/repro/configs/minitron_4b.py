"""minitron-4b — pruned nemotron [arXiv:2407.14679; hf]. squared-ReLU MLP."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv=8, d_ff=9216, vocab=256000,
    act="relu2", source="arXiv:2407.14679",
))
