"""Architecture configs: one module per assigned arch + registry."""
from repro.configs.base import (ArchConfig, ShapeSpec, SHAPES, get_arch,
                                list_archs, register, reduced)
from repro.configs import (rwkv6_1b6, codeqwen15_7b, minitron_4b, qwen3_1b7,
                           olmo_1b, musicgen_medium, qwen3_moe_235b,
                           kimi_k2_1t, paligemma_3b, zamba2_2b7)  # noqa: F401
