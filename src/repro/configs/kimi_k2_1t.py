"""kimi-k2-1t-a32b — trillion-param MoE, 384 experts top-8 + 1 shared
[arXiv:2501.kimi2; unverified, paper-table arch].

Deviation note (DESIGN.md §4): the spec table gives GQA kv=8 (not MLA) and
we make every layer MoE (the real model keeps the first layer dense).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv=8, d_ff=2048, vocab=163840,
    head_dim=112, n_experts=384, top_k=8, n_shared_experts=1,
    source="arXiv:2501.kimi2",
))
