"""paligemma-3b — SigLIP + gemma backbone [arXiv:2407.07726; hf].

Backbone only: the SigLIP vision tower is a STUB — input_specs() feeds
precomputed patch embeddings (n_prefix=256 patches) prefixed to the tokens.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv=1, d_ff=16384, vocab=257216,
    head_dim=256, act="gelu", n_prefix=256, source="arXiv:2407.07726",
))
