"""qwen3-1.7b — qk_norm + GQA [hf:Qwen/Qwen3-8B family; hf]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv=8, d_ff=6144, vocab=151936,
    qk_norm=True, head_dim=128, rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-1.7B",
))
