"""ArchConfig + input-shape registry for the 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

_REGISTRY: Dict[str, "ArchConfig"] = {}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0          # 0 -> d_model // n_heads
    qk_norm: bool = False
    norm: str = "rmsnorm"      # rmsnorm | ln_nonparam
    act: str = "swiglu"        # swiglu | gelu | relu2
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # attention schedule: skip fully-future KV blocks (beyond-paper perf)
    block_skip: bool = True
    # MoE decode-mode global dispatch (G=1) — §Perf hillclimb 2
    moe_decode_global: bool = True
    # chunkwise-parallel SSD chunk length (0 = per-step scan) — hillclimb 3
    ssd_chunk: int = 0
    # recurrent mixers
    mixer: str = "attn"        # attn | rwkv6 | mamba2
    ssm_state: int = 0
    attn_every: int = 0        # hybrid: shared attn block every k layers
    # modality frontend stub (audio/vlm): prefix embeddings via input_specs()
    n_prefix: int = 0
    dtype: str = "bfloat16"
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """True if long-context decode is feasible (SSM/hybrid)."""
        return self.mixer in ("rwkv6", "mamba2")

    def param_count(self) -> int:
        """Total parameters (embeddings + blocks + head)."""
        d, f, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.mixer == "rwkv6":
            mix = 6 * d * d + 2 * d          # r,k,v,g,o,decay (+ channel-mix in d_ff)
            ffn = 3 * d * f
            block = mix + ffn
        elif self.mixer == "mamba2":
            di = 2 * d
            block = d * (2 * di + 2 * self.ssm_state + di // 64) + di * d
            if self.attn_every:
                # one shared transformer block (attn + mlp), counted once
                shared = (2 * d * self.n_heads * self.hd
                          + 2 * d * self.n_kv * self.hd + 3 * d * f)
                emb += shared
        else:
            attn = d * self.hd * (self.n_heads * 2) + d * self.hd * self.n_kv * 2
            nglu = 3 if self.act == "swiglu" else 2
            if self.is_moe:
                ffn = (self.n_experts * 3 * d * f
                       + d * self.n_experts
                       + self.n_shared_experts * nglu * d * f)
            else:
                ffn = nglu * d * f
            block = attn + ffn
        return emb + L * block

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k + shared experts only)."""
        if not self.is_moe:
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        total = self.param_count()
        all_experts = L * self.n_experts * 3 * d * f
        active = L * self.top_k * 3 * d * f
        return total - all_experts + active


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    return _REGISTRY[name]


def list_archs():
    return sorted(_REGISTRY)


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) — long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k-context decode skipped (DESIGN.md §4)"
    return True, ""


def reduced(cfg: ArchConfig, *, n_layers: int = 2, d_model: int = 128,
            vocab: int = 512, d_ff: Optional[int] = None,
            n_experts: Optional[int] = None) -> ArchConfig:
    """Smoke-test config of the same family (small widths, few experts)."""
    hd = 32
    n_heads = max(2, d_model // hd)
    ratio = max(1, cfg.n_heads // max(1, cfg.n_kv))
    n_kv = max(1, n_heads // ratio)
    return dataclasses.replace(
        cfg,
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv=n_kv,
        head_dim=hd,
        d_ff=d_ff if d_ff is not None else d_model * 3,
        vocab=vocab,
        n_experts=(n_experts if n_experts is not None
                   else (8 if cfg.is_moe else 0)),
        top_k=min(cfg.top_k, 2) if cfg.is_moe else 0,
        # dropless at smoke scale so decode == forward exactly
        capacity_factor=4.0 if cfg.is_moe else cfg.capacity_factor,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        attn_every=min(cfg.attn_every, 2) if cfg.attn_every else 0,
        n_prefix=min(cfg.n_prefix, 8) if cfg.n_prefix else 0,
        dtype="float32",
    )
