"""qwen3-moe-235b-a22b — 128 experts top-8 [hf:Qwen/Qwen3-235B-A22B; hf]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv=4, d_ff=1536, vocab=151936,
    head_dim=128, qk_norm=True, n_experts=128, top_k=8,
    rope_theta=1_000_000.0, source="hf:Qwen/Qwen3-235B-A22B",
))
