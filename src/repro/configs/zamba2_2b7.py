"""zamba2-2.7b — Mamba2 backbone + shared attention block [arXiv:2411.15242; hf].

54 Mamba2 (SSD) blocks; ONE shared transformer block (attn kv=32 + MLP)
applied every 6 layers (weights reused each application, Zamba-style).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv=32, d_ff=10240, vocab=32000,
    head_dim=80, mixer="mamba2", ssm_state=64, attn_every=6,
    source="arXiv:2411.15242",
))
