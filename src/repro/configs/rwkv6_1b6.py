"""rwkv6-1.6b — Finch, data-dependent decay [arXiv:2404.05892; unverified]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv=32, d_ff=7168, vocab=65536,
    head_dim=64, mixer="rwkv6", act="relu2",  # rwkv channel-mix uses relu^2
    source="arXiv:2404.05892",
))
