"""Production mesh builders.

Single pod: 16x16 = 256 chips, axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model) — the 'pod' axis is
a second data-parallel axis whose collectives ride the slow inter-pod links
(which is where the compressed collectives of distributed/collectives.py —
registry-codec wire + fused dequant epilogues — earn their keep).

Functions, not module constants: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} "
            "(dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512)")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    """Small mesh for CPU multi-device tests (subprocess sets device count)."""
    n = int(np.prod(shape))
    return Mesh(np.asarray(jax.devices()[:n]).reshape(shape), axes)


def make_decode_mesh(ndev: int | None = None, axis: str = "data") -> Mesh:
    """1-D mesh over the first ``ndev`` devices (default: all) for the
    sharded decode executor (``core.plan.execute_sharded``): every device
    is one more independent decompressor for the plan's chunk rows."""
    devices = jax.devices()
    n = len(devices) if ndev is None else int(ndev)
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return Mesh(np.asarray(devices[:n]), (axis,))
