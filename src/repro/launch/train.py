"""Training driver: compressed data pipeline + fault-tolerant loop.

Runs the real thing end-to-end at any scale the host provides:
  * reduced configs on 1 CPU device (CI / examples),
  * the production mesh on a TPU slice (same code path, bigger mesh),
  * DiLoCo multi-pod training on any device set divisible into pods
    (8 virtual CPU devices in the CI smoke step).

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --preset tiny \
        --steps 50 --batch 4 --seq 128

    # compressed multi-pod training: 2 pods, int8 wire, overlapped sync
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.train --preset tiny --steps 32 \
        --diloco 2 --outer-every 8 --grad-int8

Integrates every substrate layer: CODAG-compressed token shards decoded on
device (data/pipeline.py), optionally demand-paged through the tiered blob
store (``--spill-dir``), AdamW (+ int8 moments), periodic atomic/async
checkpoints with restart (checkpoint/), straggler monitoring and failure
injection (distributed/fault.py), and the compressed collective plane:
``--grad-int8`` pushes gradients through the real int8 bitpack wire +
DecodePlan decode (distributed/collectives.py), ``--diloco N`` trains N
pods with registry-codec compressed outer syncs (``--topk`` switches the
wire to top-k values + 1-bit bitmap) overlapped with the next window's
inner steps.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.data import pipeline
from repro.distributed import fault
from repro.launch import steps as steps_lib
from repro.models import model
from repro.optim import adamw


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--preset", choices=("tiny", "small", "100m", "full"),
                    default="tiny")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--codec", default="rle_v2")
    ap.add_argument("--spill-dir", default=None,
                    help="route token shards through the tiered blob store "
                         "(disk-backed, demand-paged) instead of host RAM")
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--grad-int8", action="store_true",
                    help="push gradients through the int8 bitpack wire + "
                         "DecodePlan decode (collectives.make_wire_compressor)")
    ap.add_argument("--compress-moments", action="store_true")
    ap.add_argument("--diloco", type=int, default=0, metavar="N_PODS",
                    help="train N pods DiLoCo-style (devices reshaped to "
                         "(pod, data)); outer syncs move compressed bytes")
    ap.add_argument("--outer-every", type=int, default=16,
                    help="inner steps per DiLoCo outer sync window (H)")
    ap.add_argument("--outer-wire", choices=("int8", "topk", "none"),
                    default="int8",
                    help="DiLoCo outer-sync wire format ('none' = "
                         "uncompressed f32 psum baseline)")
    ap.add_argument("--topk", type=float, default=0.0, metavar="FRAC",
                    help="outer-sync wire: top-FRAC values + 1-bit bitmap "
                         "with error feedback (implies --outer-wire topk)")
    ap.add_argument("--link-rtt", type=float, default=0.0,
                    help="injected inter-pod link RTT seconds, for "
                         "measuring sync/compute overlap on CPU")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--compile-cache", nargs="?", const=True, default=None,
                    metavar="DIR",
                    help="persistent jit compilation cache (optional dir; "
                         "default dir when given bare)")
    return ap


def _resolve_cfg(args):
    base = get_arch(args.arch)
    if args.preset == "tiny":
        return reduced(base)
    if args.preset == "small":
        return reduced(base, n_layers=4, d_model=256, vocab=2048)
    if args.preset == "100m":
        return dataclasses.replace(
            reduced(base, n_layers=12, d_model=768, vocab=32768, d_ff=2304),
            dtype="float32")
    return base


def _build_loader(args, cfg):
    corpus = pipeline.synthetic_corpus(
        max(args.batch * args.seq * 8, 1 << 18), cfg.vocab)
    store = pipeline.CompressedTokenStore.build(
        corpus, cfg.vocab, codec=args.codec, spill_dir=args.spill_dir)
    print(f"token store: {len(store.blobs)} shards, "
          f"compression ratio {store.ratio:.3f} ({args.codec}"
          f"{', spilled' if args.spill_dir else ''})")
    return pipeline.CompressedLoader(store, args.batch, args.seq)


def _stack_batches(it, n_pods: int):
    bs = [next(it) for _ in range(n_pods)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *bs)


def _run_diloco(args, cfg, loader) -> dict:
    """N-pod DiLoCo loop: vmapped inner steps, compressed outer syncs
    overlapped with the next window (OuterSyncPipeline)."""
    from jax.sharding import Mesh
    from repro.distributed import collectives, diloco

    ndev = len(jax.devices())
    n_pods = args.diloco
    if ndev % n_pods:
        raise SystemExit(f"--diloco {n_pods} does not divide {ndev} devices")
    mesh = Mesh(np.array(jax.devices()).reshape(n_pods, ndev // n_pods),
                ("pod", "data"))
    wire = "topk" if args.topk > 0 else args.outer_wire
    dcfg = diloco.DiLoCoConfig(inner_steps=args.outer_every, wire=wire,
                               compress=(wire != "none"),
                               topk_frac=args.topk or 0.01)
    opt_cfg = adamw.AdamWConfig(lr=args.lr,
                                compress_moments=args.compress_moments)
    params = model.init_params(cfg, jax.random.key(0))
    opt_state = adamw.init(params, opt_cfg)
    compressor = (collectives.make_wire_compressor()
                  if args.grad_int8 else None)
    inner = jax.jit(steps_lib.build_pod_inner_step(
        cfg, opt_cfg, grad_compressor=compressor))

    pod_params = diloco.replicate_for_pods(params, n_pods, mesh)
    pod_opt = diloco.replicate_for_pods(opt_state, n_pods, mesh)
    outer = diloco.init_outer_state(params, mesh=mesh, cfg=dcfg)
    sync = jax.jit(diloco.make_outer_sync(mesh, dcfg))
    pipe = diloco.OuterSyncPipeline(sync, link_rtt_s=args.link_rtt)

    it = iter(loader)
    losses = []
    t0 = time.time()
    for step in range(args.steps):
        if step and step % dcfg.inner_steps == 0:
            # finish the PREVIOUS window's sync (its collective ran under
            # this window's inner steps), then launch the next one.
            if pipe.in_flight:
                pod_params, outer = pipe.finish(pod_params)
            pipe.launch(pod_params, outer)
        batch = _stack_batches(it, n_pods)
        pod_params, pod_opt, loss = inner(pod_params, pod_opt, batch)
        losses.append(float(jnp.mean(loss)))
        if args.log_every and (step + 1) % args.log_every == 0:
            print(f"step {step+1}: loss={losses[-1]:.4f}")
    if pipe.in_flight:
        pod_params, outer = pipe.finish(pod_params)
    dt = time.time() - t0

    wire_rep = collectives.wire_report(params, n_pods, wire=wire,
                                       frac=dcfg.topk_frac)
    return {"losses": losses, "seconds": dt, "steps_done": args.steps,
            "restarts": 0, "stragglers": 0,
            "tokens_per_step": n_pods * args.batch * args.seq,
            "overlap": pipe.stats(), "wire": wire_rep,
            "n_pods": n_pods}


def _run_single(args, cfg, loader) -> dict:
    opt_cfg = adamw.AdamWConfig(lr=args.lr,
                                compress_moments=args.compress_moments)
    params = model.init_params(cfg, jax.random.key(0))
    opt_state = adamw.init(params, opt_cfg)
    if args.grad_int8:
        from repro.distributed import collectives
        compressor = collectives.make_wire_compressor()
    else:
        compressor = None
    raw_step = steps_lib.build_train_step(cfg, opt_cfg,
                                          grad_compressor=compressor)
    jit_step = jax.jit(raw_step, donate_argnums=(0, 1))

    def step_fn(state, batch):
        params, opt_state = state
        params, opt_state, loss = jit_step(params, opt_state, batch)
        return (params, opt_state), loss

    injector = fault.FailureInjector(args.fail_at) if args.fail_at else None
    monitor = fault.StepMonitor()
    runner = fault.FaultTolerantRunner(
        step_fn, args.ckpt_dir, ckpt_every=args.ckpt_every, monitor=monitor,
        injector=injector)

    t0 = time.time()
    (params, opt_state), report = runner.run(
        (params, opt_state), iter(loader), args.steps)
    dt = time.time() - t0
    return {"losses": report.losses, "seconds": dt,
            "steps_done": report.steps_done, "restarts": report.restarts,
            "stragglers": report.stragglers,
            "tokens_per_step": args.batch * args.seq}


def run_training(args) -> dict:
    """Drive one training run; returns a metrics dict (losses, timings,
    wire/overlap stats for DiLoCo runs).  Importable — the collectives
    benchmark calls this in forced-device-count subprocesses."""
    if args.compile_cache:
        from repro.core import tuning
        path = tuning.enable_compile_cache(
            None if args.compile_cache is True else args.compile_cache)
        print(f"compile cache: {path}")

    cfg = _resolve_cfg(args)
    print(f"arch={cfg.name} preset={args.preset} "
          f"params~{cfg.param_count()/1e6:.1f}M")
    loader = _build_loader(args, cfg)
    if args.diloco:
        return _run_diloco(args, cfg, loader)
    return _run_single(args, cfg, loader)


def main() -> None:
    args = build_parser().parse_args()
    m = run_training(args)
    losses, dt = m["losses"], m["seconds"]
    print(f"done: {m['steps_done']} steps in {dt:.1f}s "
          f"({m['tokens_per_step'] * len(losses) / dt:.0f} tok/s), "
          f"restarts={m['restarts']} stragglers={m['stragglers']}")
    if "wire" in m:
        w, o = m["wire"], m["overlap"]
        print(f"outer wire: {w['wire_bytes']:.0f}B vs f32 ring "
              f"{w['f32_ring_bytes']:.0f}B ({w['ratio']:.1f}x); "
              f"overlap: {o['syncs']} syncs, "
              f"hidden {o['overlap_frac']*100:.0f}% of "
              f"{o['collective_s']:.2f}s collective")
    k = max(1, len(losses) // 10)
    print(f"loss: first10={np.mean(losses[:k]):.4f} "
          f"last10={np.mean(losses[-k:]):.4f}")
    assert np.mean(losses[-k:]) < np.mean(losses[:k]), "loss did not improve"
    print("OK")


if __name__ == "__main__":
    main()
