"""Training driver: compressed data pipeline + fault-tolerant loop.

Runs the real thing end-to-end at any scale the host provides:
  * reduced configs on 1 CPU device (CI / examples),
  * the production mesh on a TPU slice (same code path, bigger mesh).

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --preset tiny \
        --steps 50 --batch 4 --seq 128

Integrates every substrate layer: CODAG-compressed token shards decoded on
device (data/pipeline.py), AdamW (+ int8 moments), periodic atomic/async
checkpoints with restart (checkpoint/), straggler monitoring and failure
injection (distributed/fault.py), optional int8 gradient wire format
(optim/grad_compress.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.data import pipeline
from repro.distributed import fault
from repro.launch import steps as steps_lib
from repro.models import model
from repro.optim import adamw, grad_compress


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--preset", choices=("tiny", "small", "100m", "full"),
                    default="tiny")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--codec", default="rle_v2")
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--grad-int8", action="store_true")
    ap.add_argument("--compress-moments", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--compile-cache", nargs="?", const=True, default=None,
                    metavar="DIR",
                    help="persistent jit compilation cache (optional dir; "
                         "default dir when given bare)")
    args = ap.parse_args()

    if args.compile_cache:
        from repro.core import tuning
        path = tuning.enable_compile_cache(
            None if args.compile_cache is True else args.compile_cache)
        print(f"compile cache: {path}")

    base = get_arch(args.arch)
    if args.preset == "tiny":
        cfg = reduced(base)
    elif args.preset == "small":
        cfg = reduced(base, n_layers=4, d_model=256, vocab=2048)
    elif args.preset == "100m":
        cfg = dataclasses.replace(
            reduced(base, n_layers=12, d_model=768, vocab=32768, d_ff=2304),
            dtype="float32")
    else:
        cfg = base
    print(f"arch={cfg.name} preset={args.preset} "
          f"params~{cfg.param_count()/1e6:.1f}M")

    # --- compressed data pipeline -----------------------------------------
    corpus = pipeline.synthetic_corpus(
        max(args.batch * args.seq * 8, 1 << 18), cfg.vocab)
    store = pipeline.CompressedTokenStore.build(
        corpus, cfg.vocab, codec=args.codec)
    print(f"token store: {len(store.blobs)} shards, "
          f"compression ratio {store.ratio:.3f} ({args.codec})")
    loader = pipeline.CompressedLoader(store, args.batch, args.seq)

    # --- state + step ------------------------------------------------------
    opt_cfg = adamw.AdamWConfig(lr=args.lr,
                                compress_moments=args.compress_moments)
    params = model.init_params(cfg, jax.random.key(0))
    opt_state = adamw.init(params, opt_cfg)
    compressor = grad_compress.quantize_grads if args.grad_int8 else None
    raw_step = steps_lib.build_train_step(cfg, opt_cfg,
                                          grad_compressor=compressor)
    jit_step = jax.jit(raw_step, donate_argnums=(0, 1))

    def step_fn(state, batch):
        params, opt_state = state
        params, opt_state, loss = jit_step(params, opt_state, batch)
        return (params, opt_state), loss

    injector = fault.FailureInjector(args.fail_at) if args.fail_at else None
    monitor = fault.StepMonitor()
    runner = fault.FaultTolerantRunner(
        step_fn, args.ckpt_dir, ckpt_every=args.ckpt_every, monitor=monitor,
        injector=injector)

    t0 = time.time()
    (params, opt_state), report = runner.run(
        (params, opt_state), iter(loader), args.steps)
    dt = time.time() - t0

    losses = report.losses
    tok_per_step = args.batch * args.seq
    print(f"done: {report.steps_done} steps in {dt:.1f}s "
          f"({tok_per_step * len(losses) / dt:.0f} tok/s), "
          f"restarts={report.restarts} stragglers={report.stragglers}")
    k = max(1, len(losses) // 10)
    print(f"loss: first10={np.mean(losses[:k]):.4f} "
          f"last10={np.mean(losses[-k:]):.4f}")
    assert np.mean(losses[-k:]) < np.mean(losses[:k]), "loss did not improve"
    print("OK")


if __name__ == "__main__":
    main()
