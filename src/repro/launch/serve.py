"""Serving driver: sequential per-token prefill + cached decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --preset tiny --batch 8 --prompt-len 64 --gen 32

Demonstrates the inference path the decode_32k / long_500k dry-run shapes
lower: a batch of requests is prefilled into the KV / recurrent-state cache
ONE TOKEN POSITION PER STEP (`prefill_into_cache` loops `decode_step` over
the prompt — batched across requests, sequential over positions; a true
multi-token prefill kernel would need cache-populating full-sequence
forwards for every arch family), then decoded greedily one token per step.
Prefill timings printed here are therefore per-token-loop numbers, not
batched-prefill numbers.  Supports int8 KV-cache via --kv-int8 (the paper's
bitpack/dequant technique applied to the serving data plane).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import model


def prefill_into_cache(cfg, params, cache, tokens):
    """Sequential prefill via decode steps (cache-filling reference path)."""
    step = jax.jit(lambda p, c, t: model.decode_step(cfg, p, c, t))
    logits = None
    for i in range(tokens.shape[1]):
        logits, cache = step(params, cache, tokens[:, i:i + 1])
    return logits, cache


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--preset", choices=("tiny", "small", "full"), default="tiny")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--compile-cache", nargs="?", const=True, default=None,
                    metavar="DIR",
                    help="persistent jit compilation cache (optional dir; "
                         "default dir when given bare)")
    args = ap.parse_args()

    if args.compile_cache:
        from repro.core import tuning
        path = tuning.enable_compile_cache(
            None if args.compile_cache is True else args.compile_cache)
        print(f"compile cache: {path}")

    base = get_arch(args.arch)
    cfg = {"tiny": reduced(base),
           "small": reduced(base, n_layers=4, d_model=256, vocab=2048),
           "full": base}[args.preset]
    print(f"arch={cfg.name} preset={args.preset}")

    params = model.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(
        0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)

    max_seq = args.prompt_len + args.gen + 8
    cache = model.init_cache(cfg, args.batch, max_seq)

    t0 = time.time()
    logits, cache = prefill_into_cache(cfg, params, cache, prompts)
    t_prefill = time.time() - t0

    step = jax.jit(lambda p, c, t: model.decode_step(cfg, p, c, t))
    out_tokens = []
    cur = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t0 = time.time()
    for _ in range(args.gen):
        out_tokens.append(np.asarray(cur))
        logits, cache = step(params, cache, cur)
        cur = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.time() - t0

    gen = np.concatenate(out_tokens, axis=1)
    tok_s = args.batch * args.gen / t_decode
    print(f"prefill (per-token loop): {args.batch}x{args.prompt_len} "
          f"in {t_prefill:.2f}s")
    print(f"decode:  {args.batch}x{args.gen} in {t_decode:.2f}s "
          f"({tok_s:.1f} tok/s)")
    print("sample tokens:", gen[0, :16].tolist())
    assert gen.shape == (args.batch, args.gen)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))
    print("OK")


if __name__ == "__main__":
    main()
