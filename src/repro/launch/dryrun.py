import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import (jax locks the device
count at first init).  512 host devices back both the single-pod 16x16 mesh
and the 2x16x16 multi-pod mesh.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi

Results (cost analysis, memory analysis, per-op collective bytes, roofline
terms) are appended incrementally to experiments/dryrun_results.json so an
interrupted sweep resumes where it left off.
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax

from repro import configs
from repro.configs.base import SHAPES, get_arch, shape_applicable
from repro.distributed import sharding
from repro.launch import mesh as mesh_lib
from repro.launch import steps
from repro.roofline import analysis

RESULTS = Path("experiments/dryrun_results.json")


def _mem_dict(ma) -> dict:
    if ma is None:
        return {}
    fields = ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes")
    return {f: int(getattr(ma, f, 0) or 0) for f in fields}


# --- §Perf variants ---------------------------------------------------------
# Each variant transforms (cfg, policy, param_transform); baselines are the
# untagged cells.  See EXPERIMENTS.md §Perf for the hypothesis log.

def _quantize_params(params):
    from repro.models import moe as moe_lib
    out = dict(params)
    if "blocks" in out and isinstance(out["blocks"], dict) \
            and "moe" in out["blocks"]:
        blocks = dict(out["blocks"])
        blocks["moe"] = moe_lib.abstract_quantize_expert_weights(
            blocks["moe"])
        out["blocks"] = blocks
    return out


VARIANTS = {
    "": dict(),
    # paper-faithful attention (no triangular block skip) — the baseline
    # against which block_skip's FLOP halving is measured
    "noskip": dict(cfg=lambda c: dataclasses.replace(c, block_skip=False)),
    # hillclimb 1: fold 'model' axis into pure DP (small attn-free models)
    "dp": dict(policy="dp"),
    # hillclimb 2 (a): per-group decode dispatch (the pre-fix baseline)
    "moe_groupdecode": dict(
        cfg=lambda c: dataclasses.replace(c, moe_decode_global=False)),
    # hillclimb 2 (b): int8 expert weights, dequantized on use
    "quantx": dict(param_transform=_quantize_params),
    # hillclimb 3: chunkwise-parallel SSD
    "ssd128": dict(cfg=lambda c: dataclasses.replace(c, ssd_chunk=128)),
    "ssd256": dict(cfg=lambda c: dataclasses.replace(c, ssd_chunk=256)),
}


def _compile_cell(cfg, shape, mesh, unroll: bool, param_transform=None):
    """Lower + compile one (cfg, shape) on mesh. Returns compiled object."""
    if shape.kind == "train":
        params, opt_state = steps.abstract_train_state(cfg)
        (p_sh, o_sh, b_sh), out_sh = steps.train_shardings(cfg, shape, mesh)
        fn = steps.build_train_step(cfg, unroll=unroll)
        lowered = jax.jit(
            fn, in_shardings=(p_sh, o_sh, b_sh), out_shardings=out_sh,
            donate_argnums=(0, 1),
        ).lower(params, opt_state, steps.input_specs(cfg, shape))
    elif shape.kind == "prefill":
        params = steps.abstract_params_cached(cfg)
        if param_transform:
            params = param_transform(params)
        p_sh = sharding.param_shardings(params, mesh)
        b_sh = steps.batch_shardings(cfg, shape, mesh)
        fn = steps.build_prefill_step(cfg, unroll=unroll)
        lowered = jax.jit(
            fn, in_shardings=(p_sh, b_sh), out_shardings=None,
        ).lower(params, steps.input_specs(cfg, shape))
    else:  # decode
        params = steps.abstract_params_cached(cfg)
        if param_transform:
            params = param_transform(params)
        cache = steps.abstract_cache(cfg, shape)
        p_sh = sharding.param_shardings(params, mesh)
        cspec = sharding.cache_spec(mesh, cfg, shape.global_batch)
        from jax.sharding import NamedSharding, PartitionSpec as P
        c_sh = {k: NamedSharding(mesh, v) for k, v in cspec.items()}
        b_sh = steps.batch_shardings(cfg, shape, mesh)
        out_sh = (NamedSharding(mesh, P()), c_sh)
        fn = steps.build_serve_step(cfg, unroll=unroll)
        lowered = jax.jit(
            fn, in_shardings=(p_sh, c_sh, b_sh), out_shardings=out_sh,
            donate_argnums=(1,),
        ).lower(params, cache, steps.input_specs(cfg, shape))
    return lowered.compile()


def _raw_costs(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    coll = analysis.collective_bytes(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": coll}


def _lincomb(a: dict, b: dict, fa: float, fb: float) -> dict:
    keys = set(a["coll"]) | set(b["coll"])
    return {
        "flops": fa * a["flops"] + fb * b["flops"],
        "bytes": fa * a["bytes"] + fb * b["bytes"],
        "coll": {k: max(0.0, fa * a["coll"].get(k, 0)
                        + fb * b["coll"].get(k, 0)) for k in keys},
    }


def probe_costs(cfg, shape, mesh, param_transform=None) -> dict:
    """Exact per-device HLO costs via two python-unrolled probe lowerings.

    XLA's cost_analysis counts loop bodies once, so the full-scale
    scan-over-layers compile undercounts by ~n_layers.  We instead lower
    the model at p1 and p2 = 2*p1 layers with every structural loop
    python-unrolled (layers, attention chunks, loss chunks) and extrapolate
    linearly: cost(L) = cost(p1) + (L-p1)/g * (cost(p2)-cost(p1)), with
    g = attn_every (zamba2's shared block recurs every g layers) else 1.
    Remaining undercount: the rwkv6/mamba2 *time-step* recurrence bodies
    (<2% of mixer FLOPs — projections dominate and are counted exactly).

    dtype note: probes lower in f32.  XLA:CPU has no native bf16 GEMM and
    materializes an f32 COPY of every bf16 weight per use (verified on the
    1T MoE decode cell: 2.1x bytes inflation), which would poison the
    memory/collective terms.  f32 probes have no conversion copies; bytes
    and collective volumes are scaled by 0.5 to model TPU-native bf16
    (f32 optimizer-moment traffic is thereby understated 2x — it is ZeRO-
    sharded 16-way and small; documented in EXPERIMENTS.md §Roofline).
    FLOP counts are dtype-independent.
    """
    g = cfg.attn_every if cfg.attn_every else 1
    p1, p2 = g, 2 * g
    cfg1 = dataclasses.replace(cfg, n_layers=p1, dtype="float32")
    cfg2 = dataclasses.replace(cfg, n_layers=p2, dtype="float32")
    c1 = _raw_costs(_compile_cell(cfg1, shape, mesh, unroll=True,
                                  param_transform=param_transform))
    c2 = _raw_costs(_compile_cell(cfg2, shape, mesh, unroll=True,
                                  param_transform=param_transform))
    steps_n = (cfg.n_layers - p1) / g
    out = _lincomb(c1, _lincomb(c2, c1, 1.0, -1.0), 1.0, steps_n)
    out["bytes"] *= 0.5
    out["coll"] = {k: v * 0.5 for k, v in out["coll"].items()}
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             extra_tag: str = "", probes: bool = True,
             variant: str = "") -> dict:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"status": "skipped", "reason": why}
    vspec = VARIANTS[variant]
    if "cfg" in vspec:
        cfg = vspec["cfg"](cfg)
    policy = vspec.get("policy", "tp")
    ptrans = vspec.get("param_transform")

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n_chips = int(mesh.devices.size)
    t0 = time.time()
    with mesh, sharding.use_mesh(mesh, policy=policy):
        # 1) full-scale compile: proves sharding + memory at target scale
        compiled = _compile_cell(cfg, shape, mesh, unroll=False,
                                 param_transform=ptrans)
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        print(mem)                                    # proves it fits
        raw = _raw_costs(compiled)
        print({"flops(raw,scan)": raw["flops"], "bytes(raw,scan)": raw["bytes"]})
        # 2) probe lowerings: exact per-layer cost extrapolation
        cost = (probe_costs(cfg, shape, mesh, param_transform=ptrans)
                if probes else raw)

    mf = analysis.model_flops_for(cfg, shape)
    roof = analysis.Roofline(
        flops=cost["flops"], hbm_bytes=cost["bytes"],
        coll_bytes=float(sum(cost["coll"].values())),
        coll_by_op={k: int(v) for k, v in cost["coll"].items()},
        model_flops=mf, n_chips=n_chips)

    return {
        "status": "ok",
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "compile_s": round(t_compile, 1),
        "total_s": round(time.time() - t0, 1),
        "memory": _mem_dict(mem),
        "raw_scan_costs": {"flops": raw["flops"], "bytes": raw["bytes"],
                           "coll": raw["coll"]},
        "roofline": roof.to_dict(),
        "tag": extra_tag,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(RESULTS))
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    ap.add_argument("--variant", default="", choices=sorted(VARIANTS),
                    help="perf variant (EXPERIMENTS.md §Perf)")
    ap.add_argument("--no-probes", action="store_true",
                    help="compile + memory proof only (multi-pod pass; "
                         "the roofline table is single-pod per spec)")
    args = ap.parse_args()

    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    results = {}
    if out_path.exists():
        results = json.loads(out_path.read_text())

    archs = configs.list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                key = f"{arch}|{shape}|{'multi' if multi else 'single'}"
                if args.variant:
                    key += f"|{args.variant}"
                if key in results and results[key].get("status") in ("ok", "skipped") \
                        and not args.force:
                    print(f"[cached] {key}")
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                try:
                    res = run_cell(arch, shape, multi, args.variant,
                                   variant=args.variant,
                                   probes=not args.no_probes)
                except Exception as e:  # record failures; they are bugs
                    res = {"status": "error", "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                    print(res["error"])
                results[key] = res
                out_path.write_text(json.dumps(results, indent=1))
                if res["status"] == "ok":
                    r = res["roofline"]
                    print(f"  ok: compile={res['compile_s']}s "
                          f"dom={r['dominant']} "
                          f"t=({r['t_compute_s']:.4f},{r['t_memory_s']:.4f},"
                          f"{r['t_collective_s']:.4f})s "
                          f"useful={r['useful_flops_ratio']:.2f}", flush=True)

    n_ok = sum(1 for v in results.values() if v.get("status") == "ok")
    n_skip = sum(1 for v in results.values() if v.get("status") == "skipped")
    n_err = sum(1 for v in results.values() if v.get("status") == "error")
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
