"""Step functions (train / prefill / serve) + abstract input specs.

Everything here is AOT-friendly: `input_specs` produces ShapeDtypeStructs,
`abstract_state` builds the params/optimizer/cache trees via eval_shape, and
`build_*` return (fn, in_shardings, out_shardings, example_inputs) tuples the
dry-run lowers with `.lower().compile()` and train.py runs with real arrays.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.distributed import sharding
from repro.models import model
from repro.optim import adamw

DT = model.DTYPES


# --------------------------------------------------------------------------
# abstract inputs
# --------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    dt = DT[cfg.dtype]
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        if cfg.n_prefix:
            # modality frontend stub: precomputed frame/patch embeddings
            specs["prefix_emb"] = jax.ShapeDtypeStruct(
                (B, cfg.n_prefix, cfg.d_model), dt)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if cfg.n_prefix:
            specs["prefix_emb"] = jax.ShapeDtypeStruct(
                (B, cfg.n_prefix, cfg.d_model), dt)
        return specs
    # decode: one new token against a KV/state cache of length seq_len
    return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}


def batch_shardings(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh):
    spec = sharding.batch_spec(mesh, shape.global_batch)
    bax = spec[0] if len(spec) else None
    out = {}
    for k, v in input_specs(cfg, shape).items():
        out[k] = NamedSharding(mesh, P(*([bax] + [None] * (v.ndim - 1))))
    return out


# --------------------------------------------------------------------------
# train
# --------------------------------------------------------------------------


def build_train_step(cfg: ArchConfig, opt_cfg: Optional[adamw.AdamWConfig] = None,
                     remat: bool = True, grad_compressor=None,
                     unroll: bool = False):
    opt_cfg = opt_cfg or adamw.AdamWConfig()

    def train_step(params, opt_state, batch):
        def lf(p):
            return model.loss_fn(cfg, p, batch["tokens"], batch["labels"],
                                 batch.get("prefix_emb"), remat=remat,
                                 unroll=unroll)
        loss, grads = jax.value_and_grad(lf)(params)
        if grad_compressor is not None:
            grads = grad_compressor(grads)
        params, opt_state = adamw.apply(params, grads, opt_state, opt_cfg)
        return params, opt_state, loss

    return train_step


def build_pod_inner_step(cfg: ArchConfig,
                         opt_cfg: Optional[adamw.AdamWConfig] = None,
                         remat: bool = True, grad_compressor=None,
                         unroll: bool = False):
    """DiLoCo inner step: the train step vmapped over a leading (n_pods,)
    member axis (params/opt/batch all carry it, sharded over 'pod'), so
    each pod trains independently with NO cross-pod collective per step —
    pods reconcile only through the compressed outer sync
    (``distributed.diloco.make_outer_sync``).

    ``grad_compressor`` composes: pass
    ``distributed.collectives.make_wire_compressor()`` to push every
    inner-step gradient through the real int8 bitpack wire + DecodePlan
    decode (the optimizer consumes decode outputs, not a host-side
    dequant)."""
    from repro.distributed import diloco
    return diloco.make_inner_step(
        build_train_step(cfg, opt_cfg, remat=remat,
                         grad_compressor=grad_compressor, unroll=unroll))


def abstract_train_state(cfg: ArchConfig,
                         opt_cfg: Optional[adamw.AdamWConfig] = None):
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    params = model.abstract_params(cfg)
    opt_state = jax.eval_shape(functools.partial(adamw.init, cfg=opt_cfg), params)
    return params, opt_state


def train_shardings(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                    opt_cfg: Optional[adamw.AdamWConfig] = None):
    params, opt_state = abstract_train_state(cfg, opt_cfg)
    p_sh = sharding.param_shardings(params, mesh)
    o_sh = sharding.opt_shardings(opt_state, params, mesh)
    b_sh = batch_shardings(cfg, shape, mesh)
    return (p_sh, o_sh, b_sh), (p_sh, o_sh, NamedSharding(mesh, P()))


# --------------------------------------------------------------------------
# prefill / serve
# --------------------------------------------------------------------------


def build_prefill_step(cfg: ArchConfig, unroll: bool = False):
    def prefill_step(params, batch):
        x = model.embed_inputs(cfg, params, batch["tokens"],
                               batch.get("prefix_emb"))
        x = model._layer_stack(cfg, params, x, remat=False, unroll=unroll)
        from repro.models import layers
        x = layers.apply_norm(cfg.norm, x, params["ln_f"])
        x_last = x[:, -1:]
        head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        return x_last @ head          # (B, 1, vocab) next-token logits

    return prefill_step


def build_serve_step(cfg: ArchConfig, unroll: bool = False):
    def serve_step(params, cache, batch):
        logits, cache = model.decode_step(cfg, params, cache, batch["tokens"],
                                          unroll=unroll)
        return logits, cache

    return serve_step


def serve_shardings(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh):
    params = model.abstract_params(cfg)
    p_sh = sharding.param_shardings(params, mesh)
    cspec = sharding.cache_spec(mesh, cfg, shape.global_batch)
    c_sh = {k: NamedSharding(mesh, v) for k, v in cspec.items()}
    b_sh = batch_shardings(cfg, shape, mesh)
    lg_sh = NamedSharding(mesh, P())
    return (p_sh, c_sh, b_sh), (lg_sh, c_sh)


def abstract_cache(cfg: ArchConfig, shape: ShapeSpec):
    return model.init_cache(cfg, shape.global_batch, shape.seq_len,
                            abstract=True)


_PARAM_CACHE: Dict[str, Any] = {}


def abstract_params_cached(cfg: ArchConfig):
    if cfg.name not in _PARAM_CACHE:
        _PARAM_CACHE[cfg.name] = model.abstract_params(cfg)
    return _PARAM_CACHE[cfg.name]
