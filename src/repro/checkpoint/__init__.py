# Fault-tolerant checkpointing: atomic, async, codec-compressed, elastic.
