"""Checkpointing: atomic, async, codec-compressed, elastic restore.

* atomic     — write to ``step_N.tmp/`` then rename; a crash mid-save never
               corrupts the latest checkpoint.
* async      — the host copy is taken synchronously (consistent snapshot),
               serialization runs on a background thread; ``wait()`` joins.
* compressed — leaves can be stored through the paper's codecs
               (tdeflate for raw bytes, rle_v2 for integer state, bitpack
               for int8 moments); decode on restore uses the CODAG engine.
* elastic    — ``restore(..., shardings=...)`` re-lays the state onto a
               DIFFERENT mesh than it was saved from (node-failure recovery
               path: restart on fewer/more pods).
"""
from __future__ import annotations

import json
import pickle
import re
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.core import api as codec_api
from repro.core import registry
from repro.core.engine import CodagEngine, EngineConfig

MANIFEST = "manifest.json"
_STEP_RE = re.compile(r"step_(\d+)")


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, state, *, codec: str = "none",
         async_: bool = False, keep: int = 3) -> Optional[threading.Thread]:
    """Snapshot ``state`` (any pytree). Returns the writer thread if async."""
    root = Path(ckpt_dir)
    root.mkdir(parents=True, exist_ok=True)
    # consistent snapshot: device->host copy happens NOW, writing may defer
    host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

    def _write():
        tmp = root / f"step_{step}.tmp"
        final = root / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = _flatten(host)
        manifest = {"step": step, "codec": codec, "leaves": {}}
        for key, leaf in flat.items():
            fn = key.replace("/", "__") + ".npy"
            arr = np.asarray(leaf)
            entry = {"file": fn, "dtype": str(arr.dtype),
                     "shape": list(arr.shape), "codec": "none"}
            if codec != "none" and arr.nbytes >= 1024:
                # byte-stream codecs take any dtype as raw bytes
                ca = codec_api.compress(
                    arr.reshape(-1).view(np.uint8)
                    if registry.get(codec).byte_stream else arr, codec)
                with open(tmp / (fn + ".blob"), "wb") as f:
                    pickle.dump(ca, f)
                entry["codec"] = codec
                entry["ratio"] = ca.ratio
            else:
                np.save(tmp / fn, arr)
            manifest["leaves"][key] = entry
        (tmp / MANIFEST).write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                      # atomic publish
        # retention: only prune steps STRICTLY OLDER than the one we just
        # published — two overlapping async saves then cannot delete each
        # other's newer checkpoint, whichever writer finishes last.
        steps = sorted(all_steps(ckpt_dir))
        for s in steps[:-keep]:
            if s < step:
                shutil.rmtree(root / f"step_{s}", ignore_errors=True)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def all_steps(ckpt_dir: str):
    """Published step numbers.  Only exact ``step_<int>`` directories count;
    foreign names that merely share the prefix (``step_final``, a stray
    ``step_7.tmp``, files) are skipped instead of raising ``ValueError``."""
    root = Path(ckpt_dir)
    if not root.exists():
        return []
    steps = []
    for p in root.glob("step_*"):
        m = _STEP_RE.fullmatch(p.name)
        if m and p.is_dir():
            steps.append(int(m.group(1)))
    return steps


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


def _load_blob(path):
    """Load one compressed leaf (a pickled ``api.CompressedArray``).
    Module-level so tests can instrument load-vs-decode ordering."""
    with open(path, "rb") as f:
        return pickle.load(f)


def restore(ckpt_dir: str, step: int, like, *, shardings=None,
            engine: Optional[CodagEngine] = None,
            decode_window: Optional[int] = None,
            service=None, device_out: bool = False,
            store=None, prefetch_windows: int = 1):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings — the ELASTIC path: state saved on one mesh is re-laid
    onto whatever mesh the restarted job has.

    ``decode_window``: by default all compressed leaves decode through ONE
    batched plan (max stream count per launch); peak host memory is then a
    few multiples of the checkpoint size.  Set a window to decode that many
    leaves per plan instead — bounded memory, proportionally more
    dispatches.

    ``service``: a ``core.server.DecompressionService`` to decode through
    instead of a private engine — all leaves ride the service's micro-batch
    windows (sharing dispatches and the decoded-blob cache with any other
    concurrent restores/requests on the same service).

    ``device_out``: materialize every leaf as a device-resident jax array —
    compressed leaves decode, reassemble, and bitcast to their manifest
    dtype entirely on device (no decode→host→re-upload round trip), and
    uncompressed leaves upload once.  Requires 64-bit jax types for 8-byte
    leaf dtypes.

    ``shardings`` + ``device_out`` together are the mesh-sharded restore:
    the batched plan decodes every compressed leaf's chunk rows ACROSS the
    shardings' mesh (``DecodePlan.execute_sharded`` — each device decodes
    its share of the fused stream tables; no single-device decode
    bottleneck, zero ``transfers.to_host`` crossings), and each leaf is
    committed under its requested ``NamedSharding``.

    ``store``: a ``core.store.TieredBlobStore`` (e.g.
    ``store.filesystem_store(ckpt_dir)``) to demand-page compressed leaves
    through instead of reading blob files directly — the STREAMING restore:
    while window i decodes (plan stage + dispatch), the store's pool is
    prefetching window i+1..i+``prefetch_windows``'s blobs from disk/object
    storage, and consumed windows are released back under the store's host
    byte budget.  A checkpoint larger than host memory restores with
    resident compressed bytes bounded by ~(1+``prefetch_windows``) windows
    (``decode_window`` defaults to 8 on this path).  Without a store,
    blob files are still loaded lazily PER WINDOW, so ``decode_window``
    bounds peak host memory either way."""
    if engine is not None and service is not None:
        raise ValueError("pass engine= OR service=, not both: the service "
                         "decodes on its own engine")
    if store is not None and decode_window is None:
        decode_window = 8
    root = Path(ckpt_dir) / f"step_{step}"
    manifest = json.loads((root / MANIFEST).read_text())
    if service is None and not device_out:
        engine = engine or CodagEngine(EngineConfig())
    mesh = None
    if device_out and shardings is not None and service is None:
        mesh = next((s.mesh for s in jax.tree.leaves(shardings)
                     if isinstance(s, jax.sharding.NamedSharding)), None)

    flat_like, tdef = jax.tree_util.tree_flatten(like)
    keys = list(_flatten(like).keys())

    # Decode compressed leaves window by window.  Each window's blobs are
    # loaded LAZILY (read from disk — or demand-paged through the tiered
    # store, which is prefetching the next window while this one decodes),
    # decoded through one batched plan per codec/width group (CODAG
    # provisioning), and committed into ``leaves`` before the next window's
    # blobs materialize — peak extra host memory is ~one window of
    # compressed + decoded bytes, not the whole checkpoint.
    leaves: list = [None] * len(keys)
    comp_idx: list = []
    comp_files: list = []
    for i, key in enumerate(keys):
        entry = manifest["leaves"][key]
        if entry["codec"] != "none":
            comp_idx.append(i)
            comp_files.append(entry["file"] + ".blob")
        else:
            leaves[i] = np.load(root / entry["file"])
    w = decode_window or max(1, len(comp_files))
    if store is not None:
        prefix = f"step_{step}/"
        window_iter = store.stream_windows(
            [prefix + f for f in comp_files], window=w,
            lookahead=max(0, prefetch_windows))
    else:
        def _lazy_windows():
            for j in range(0, len(comp_files), w):
                yield [_load_blob(root / f) for f in comp_files[j:j + w]]
        window_iter = _lazy_windows()
    if device_out:
        from repro.core import format as fmt
    pos = 0
    for cas in window_iter:
        idxs = comp_idx[pos:pos + len(cas)]
        pos += len(cas)
        if service is not None:
            decoded = service.decode_arrays(cas, device_out=device_out)
        else:
            decoded = codec_api.decompress_many(cas, engine,
                                                device_out=device_out,
                                                mesh=mesh)
        for i, arr in zip(idxs, decoded):
            entry = manifest["leaves"][keys[i]]
            if device_out:
                leaves[i] = fmt.device_view(arr.reshape(-1), entry["dtype"],
                                            tuple(entry["shape"]))
            else:
                leaves[i] = (arr.reshape(-1).view(np.dtype(entry["dtype"]))
                             .reshape(entry["shape"]))
    if device_out:
        import jax.numpy as jnp

        # uncompressed leaves upload once; the astype is a device op
        leaves = [jnp.asarray(leaf).astype(
                      np.dtype(manifest["leaves"][key]["dtype"]))
                  for key, leaf in zip(keys, leaves)]
    else:
        leaves = [leaf.astype(manifest["leaves"][key]["dtype"])
                  for key, leaf in zip(keys, leaves)]
    state = tdef.unflatten(leaves)
    if shardings is not None:
        state = jax.tree.map(lambda a, s: jax.device_put(a, s),
                             state, shardings)
    return state
