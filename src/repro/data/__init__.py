# Data pipeline: compressed token shards decompressed on device
# (the paper's decompression engine in the training input path).
