"""Compressed token store + input pipeline.

Token shards are stored codec-compressed (RLE v2 by default — token streams
from natural corpora have heavy repetition/locality) and decompressed ON
DEVICE by the CODAG engine before each train step: the paper's data-analytics
pipeline pattern (§I — "read compressed data into GPU memory, run a
decompression kernel, then the query") transplanted to the training input
path.

The loader double-buffers host->device transfer of chunk i+1 against the
decode of chunk i via an async prefetch thread, mirroring the engine-level
latency-hiding story.
"""
from __future__ import annotations

import collections
import queue
import threading
from typing import Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batch as cbatch
from repro.core import encoders as enc
from repro.core import format as fmt
from repro.core import store as blobstore
from repro.core.engine import CodagEngine, EngineConfig
from repro.core.server import DecompressionService


def synthetic_corpus(n_tokens: int, vocab: int, seed: int = 0,
                     run_bias: float = 0.3) -> np.ndarray:
    """Zipf-distributed tokens with run/locality structure (compressible,
    like real BPE streams — frequent tokens + repeated n-grams)."""
    rng = np.random.default_rng(seed)
    base = rng.zipf(1.3, size=n_tokens)
    tokens = np.minimum(base - 1, vocab - 1).astype(np.uint32)
    # inject runs (repeated tokens / copied spans) for realism
    n_runs = int(n_tokens * run_bias / 8)
    starts = rng.integers(0, max(1, n_tokens - 16), n_runs)
    for s in starts:
        l = int(rng.integers(2, 9))
        tokens[s:s + l] = tokens[s]
    return tokens


class CompressedTokenStore:
    """Store of codec-compressed token shards: in-memory, or spilled to a
    ``core.store.TieredBlobStore`` (``build(spill_dir=...)``) and
    demand-paged back with lookahead prefetch — a corpus larger than host
    RAM streams through a bounded compressed-shard cache."""

    def __init__(self, blobs: List[fmt.CompressedBlob], vocab: int, *,
                 store: Optional[blobstore.TieredBlobStore] = None,
                 keys: Optional[List[str]] = None,
                 shard_meta: Optional[List[tuple]] = None):
        self.blobs = blobs
        self.vocab = vocab
        self._store = store
        self._keys = list(keys or [])
        # (compressed_bytes, uncompressed_bytes) per spilled shard, so
        # ratio/accounting never page anything back in
        self._meta = list(shard_meta or [])

    @classmethod
    def build(cls, tokens: np.ndarray, vocab: int,
              shard_tokens: int = 1 << 20,
              codec: str = fmt.RLE_V2,
              chunk_bytes: int = 64 * 1024,
              spill_dir: Optional[str] = None,
              host_budget_bytes: int = 64 << 20,
              prefetch_workers: int = 4) -> "CompressedTokenStore":
        """``spill_dir=None`` keeps every compressed shard in host RAM.
        With a ``spill_dir``, shards are written through a
        ``TieredBlobStore`` (atomic one-file-per-shard) and the store
        demand-pages them back on access, keeping at most
        ``host_budget_bytes`` of compressed shards resident."""
        shard_arrays = (tokens[i:i + shard_tokens].astype(np.uint32)
                        for i in range(0, len(tokens), shard_tokens))
        if spill_dir is None:
            return cls([enc.compress(s, codec, chunk_bytes)
                        for s in shard_arrays], vocab)
        st = blobstore.filesystem_store(
            spill_dir, host_budget_bytes=host_budget_bytes,
            prefetch_workers=prefetch_workers)
        keys, meta = [], []
        for si, s in enumerate(shard_arrays):
            b = enc.compress(s, codec, chunk_bytes)
            key = f"shard_{si:06d}.blob"
            st.put(key, b)               # write-through; not cached (admit
            keys.append(key)             # happens on first read access)
            meta.append((b.compressed_bytes, b.uncompressed_bytes))
        return cls([], vocab, store=st, keys=keys, shard_meta=meta)

    @property
    def spilled(self) -> bool:
        return self._store is not None

    @property
    def store(self) -> Optional[blobstore.TieredBlobStore]:
        """The backing ``TieredBlobStore`` (spilled mode only)."""
        return self._store

    @property
    def num_shards(self) -> int:
        return len(self._keys) if self.spilled else len(self.blobs)

    def blob(self, i: int) -> fmt.CompressedBlob:
        """Shard ``i``'s compressed blob; demand-paged in spilled mode."""
        if self.spilled:
            return self._store.get(self._keys[i])
        return self.blobs[i]

    def prefetch_shards(self, lo: int, hi: int) -> None:
        """Async lookahead: schedule shards ``[lo, hi)`` for paging-in
        (no-op for the in-memory store)."""
        if self.spilled:
            self._store.prefetch(self._keys[max(0, lo):hi])

    def _blob_windows(self, window: int,
                      lookahead: int = 1) -> Iterator[List[fmt.CompressedBlob]]:
        """Shard blobs in windows; spilled mode overlaps the next window's
        paging with the consumer's decode of the current one
        (``TieredBlobStore.stream_windows``) and releases consumed windows
        back under the host budget."""
        if not self.spilled:
            for i in range(0, len(self.blobs), window):
                yield self.blobs[i:i + window]
            return
        yield from self._store.stream_windows(self._keys, window=window,
                                              lookahead=lookahead)

    @property
    def ratio(self) -> float:
        if self.spilled:
            c = sum(m[0] for m in self._meta)
            u = sum(m[1] for m in self._meta)
        else:
            c = sum(b.compressed_bytes for b in self.blobs)
            u = sum(b.uncompressed_bytes for b in self.blobs)
        return c / max(1, u)

    def decoded_shards(self, engine: CodagEngine, window: int = 1,
                       device_out: bool = False,
                       mesh=None) -> Iterator[np.ndarray]:
        """Decode shards; ``window`` > 1 coalesces that many shards' chunks
        into one batched dispatch per codec group (CODAG provisioning) while
        bounding peak host memory to ~window uncompressed shards.
        ``device_out=True`` yields device-resident int32 jax arrays —
        decode, reassembly, and the int32 widening never visit the host.
        ``mesh`` (implies device out) decodes each shard's chunk rows
        across the mesh's data-axis devices and yields token shards BORN
        sharded over that axis (``NamedSharding`` on the token dim) — the
        input pipeline feeds a data-parallel step without a gather."""
        device_out = device_out or mesh is not None
        out_sh = None
        if mesh is not None:
            from repro.distributed import sharding as shd
            out_sh = shd.decode_out_sharding(mesh)
            # the int32 widening is a device op too; re-commit under the
            # data-axis sharding so the yielded shard carries it verbatim
            # (ragged tail shards that cannot satisfy the spec stay put)
            from repro.core import plan as cplan
            cast = lambda a: (jax.device_put(a.astype(jnp.int32), out_sh)
                              if cplan.placeable(a.shape, out_sh)
                              else a.astype(jnp.int32))
        elif device_out:
            cast = lambda a: a.astype(jnp.int32)
        else:
            cast = lambda a: a.astype(np.int32)
        for blobs in self._blob_windows(max(1, window)):
            for out in cbatch.decompress_blobs(
                    blobs, engine,
                    device_out=device_out, mesh=mesh, out_shardings=out_sh):
                yield cast(out)

    def decoded_shards_async(self, service: DecompressionService,
                             lookahead: int = 4,
                             device_out: bool = False) -> Iterator[np.ndarray]:
        """Decode shards through a ``DecompressionService``: keep up to
        ``lookahead`` shard requests in flight and yield results in order.
        The service worker overlaps decode of shard i+1..i+lookahead with
        the consumer's use of shard i (and coalesces the in-flight shards
        into fused dispatches), replacing the loader's ad-hoc prefetch
        thread.  ``device_out=True`` serves device-resident shards."""
        cast = (lambda a: a.astype(jnp.int32)) if device_out \
            else (lambda a: a.astype(np.int32))
        n = self.num_shards
        look = max(1, lookahead)
        futs: "collections.deque" = collections.deque()
        idx = 0
        self.prefetch_shards(0, look)      # prime the paging pipeline
        while idx < n and len(futs) < look:
            self.prefetch_shards(idx + 1, idx + 1 + look)
            futs.append(service.submit(self.blob(idx),
                                       device_out=device_out))
            idx += 1
        while futs:
            out = futs.popleft().result()
            if idx < n:
                # shard idx pages in (hit — its fetch was issued a step
                # ago) while idx+1..idx+look stream in behind it
                self.prefetch_shards(idx + 1, idx + 1 + look)
                futs.append(service.submit(self.blob(idx),
                                           device_out=device_out))
                idx += 1
            yield cast(out)


class CompressedLoader:
    """Batches (tokens, labels) from a CompressedTokenStore with on-device
    decompression and async prefetch.

    Peak decoded-shard buffering is ``decode_window`` (shards fused into one
    batched dispatch, materialized together) plus the prefetch queue's 2 —
    not the single shard of the pre-batching loader.  ``decode_window=1``
    restores the old one-shard-per-dispatch behavior.

    ``service``: decode through a shared ``DecompressionService`` instead of
    a private engine + prefetch thread.  The loader keeps ``decode_window``
    shard requests in flight (``decoded_shards_async``): the service worker
    owns the decode concurrency, coalesces the in-flight shards into fused
    dispatches, and its decoded-blob cache makes repeat epochs over the same
    shards dispatch-free.

    ``device_out``: feed device shards end to end — shards decode to
    device-resident arrays and the batch slicing / vocab clamp are device
    ops, so token data crosses host→device once (the compressed upload) and
    never comes back."""

    def __init__(self, store: CompressedTokenStore, batch: int, seq: int,
                 engine: Optional[CodagEngine] = None, prefetch: bool = True,
                 decode_window: int = 4,
                 service: Optional[DecompressionService] = None,
                 device_out: bool = False, mesh=None):
        if service is not None and mesh is not None:
            raise ValueError("mesh= is not supported with service=: the "
                             "service decodes on its own single-engine "
                             "worker; use the engine path for sharded "
                             "token shards")
        self.store = store
        self.batch = batch
        self.seq = seq
        self.engine = engine or CodagEngine(EngineConfig())
        self.prefetch = prefetch
        # shards whose chunks are fused into one batched decode dispatch
        # (engine mode) or kept in flight on the service (service mode)
        self.decode_window = decode_window
        self.service = service
        # mesh: decode every shard's rows across the mesh's data-axis
        # devices; token shards enter the batch assembly born sharded
        self.mesh = mesh
        self.device_out = device_out or mesh is not None

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        need = self.batch * self.seq + 1
        xp = jnp if self.device_out else np
        buf = xp.zeros(0, xp.int32)

        def shard_iter():
            while True:  # loop over shards forever
                if self.service is not None:
                    yield from self.store.decoded_shards_async(
                        self.service, lookahead=self.decode_window,
                        device_out=self.device_out)
                else:
                    yield from self.store.decoded_shards(
                        self.engine, window=self.decode_window,
                        device_out=self.device_out, mesh=self.mesh)

        src = shard_iter()
        t = None
        stop = threading.Event()
        if self.prefetch and self.service is None:
            q: "queue.Queue" = queue.Queue(maxsize=2)

            def worker():
                # Bounded-timeout puts + a stop flag: when the consumer
                # drops the iterator, the worker exits within one timeout
                # instead of blocking on q.put forever holding a decoded
                # shard (the old leak — one zombie thread per dropped
                # iterator).  Stop is also checked before each decode so
                # shutdown never waits on another shard's dispatch.
                while not stop.is_set():
                    try:
                        s = next(src)
                    except StopIteration:
                        return
                    while not stop.is_set():
                        try:
                            q.put(s, timeout=0.05)
                            break
                        except queue.Full:
                            continue

            t = threading.Thread(target=worker, daemon=True,
                                 name="codag-loader-prefetch")
            t.start()
            get = q.get
        else:
            # service mode: the service worker already decodes ahead of the
            # consumer — no ad-hoc prefetch thread needed.
            get = lambda: next(src)

        try:
            while True:
                while len(buf) < need:
                    buf = xp.concatenate([buf, get()])
                flat = buf[:need]
                buf = buf[need - 1:]
                toks = (flat[:-1].reshape(self.batch, self.seq)
                        % self.store.vocab)
                labs = (flat[1:].reshape(self.batch, self.seq)
                        % self.store.vocab)
                yield {"tokens": jnp.asarray(toks),
                       "labels": jnp.asarray(labs)}
        finally:
            # runs on generator close/GC as well as break/throw: shut the
            # prefetch worker down so no thread outlives its iterator
            if t is not None:
                stop.set()
                try:
                    while True:
                        q.get_nowait()       # unblock a mid-put worker
                except queue.Empty:
                    pass
                t.join(timeout=5.0)
