"""DecodePlan — the unified decode-pipeline IR every entry path lowers to.

CODAG's throughput story is provisioning: a launch must carry as many
independent decode streams as the hardware exposes.  The repo grew four
public decode entry paths (``engine.decompress*``, ``api.decompress_many``,
``batch.BatchPlan``, ``server.DecompressionService``) that each
re-implemented the same group → stage → dispatch → reassemble sequence.
This module is that sequence, written once, as an inspectable IR:

    parse/group  — partition blobs by ``(codec, width, chunk_elems, bits)``
                   and fuse each group's chunk tables into one flat stream
                   table (``format.concat_blobs``); precompute every blob's
                   scatter (``format.reassemble_indices``).
    stage        — upload fused tables, scatter indices, and epilogue
                   operands through the ``transfers.to_device`` funnel
                   (once; staged plans re-execute transfer-free).
    dispatch     — ONE ``ops.decode`` lowering site for the whole repo
                   (:func:`dispatch`), covering both the warp (CODAG) and
                   block (RAPIDS-ablation) provisioning units.
    reassemble   — per-blob row-range scatter back to original arrays
                   (``format.reassemble_rows_device``), on device.
    epilogue     — optional fused consumer transform
                   (``kernels.harness.Epilogue``) inside the dispatch.
    place        — commit each output under a caller-supplied
                   ``jax.sharding`` placement, so results are *born* where
                   the consumer wants them.

On top of the single-device executors sits the **sharded executor**
(:meth:`DecodePlan.execute_sharded`): a plan's groups are row-partitioned
across one axis of a ``jax.sharding.Mesh`` — every device decodes its local
slice of each fused stream table via ``shard_map`` (per-device uniform
padding with zero-length chunks keeps the grid rectangular) and outputs are
born under the requested ``NamedSharding``.  A mesh of D devices is just
more of the hardware CODAG already provisions for: D independent
decompressors, each saturated with its share of the streams, no all-gather
and no single-device bottleneck.

    plan = DecodePlan.build(blobs)
    outs = plan.execute(engine)                     # host ndarrays
    devs = plan.execute_device(engine)              # device arrays, zero d2h
    shrd = plan.execute_sharded(mesh)               # rows decoded per device
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import functools
import hashlib
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import format as fmt
from repro.core import transfers
from repro.kernels import ops

# Bounded digest-keyed LRU slots for staged epilogue operands: a consumer
# alternating between a handful of operand dicts (e.g. two quantized layers
# sharing one plan) stays transfer-free without letting a pathological
# caller grow device memory unboundedly.
OPERAND_CACHE_SLOTS = 8


def _default_engine(engine):
    if engine is not None:
        return engine
    from repro.core.engine import CodagEngine, EngineConfig
    return CodagEngine(EngineConfig())


# --------------------------------------------------------------------------
# dispatch — the ONE ops.decode lowering site in the repo
# --------------------------------------------------------------------------

# Lowering observers (``count_lowered``): same discipline as
# ``ops.count_dispatches`` — list-of-lists under a lock, so the registry
# gate can prove every engine dispatch originated here.
_lowered: list = []
_lowered_lock = threading.Lock()


@contextlib.contextmanager
def count_lowered():
    """Observe plan-level :func:`dispatch` calls (the lowering funnel).

    Paired with ``ops.count_dispatches``, equal counts prove that every
    kernel launch was lowered through the plan IR — the registry CI gate
    fails any codec whose decode path bypasses it.
    """
    calls: list = []
    with _lowered_lock:
        _lowered.append(calls)
    try:
        yield calls
    finally:
        with _lowered_lock:
            for i, obs in enumerate(_lowered):
                if obs is calls:
                    del _lowered[i]
                    break


def dispatch(dev: Dict[str, Any], *, config, codec: str, width: int,
             chunk_elems: int, bits: int = 0, epilogue=None, tune=None):
    """Stage 3 of the pipeline: lower one fused chunk table to ``ops.decode``.

    ``config`` is an ``engine.EngineConfig`` (hashable, jit-static): it
    selects the provisioning unit — ``warp`` issues the whole table as one
    launch of independent streams (CODAG); ``block`` reproduces the
    fixed-pool RAPIDS baseline by scanning serial batches of ``n_units``
    streams.  This function is the only ``ops.decode`` call site outside
    the kernels layer — every entry path's decode lowers through it.

    ``tune``: the static kernel-knob tuple (``core.tuning.kernel_tune``).
    ``None`` resolves tuned defaults merged with ``config.tune`` here —
    only safe outside an outer jit trace; the plan's jitted executors
    resolve it eagerly and pass it through as a static argument.
    """
    import jax
    import jax.numpy as jnp

    if tune is None:
        from repro.core import tuning
        tune = tuning.kernel_tune(codec, width, getattr(config, "tune", ()))

    with _lowered_lock:
        if _lowered:
            rec = {"num_chunks": int(dev["comp"].shape[0]), "codec": codec,
                   "width": width, "chunk_elems": chunk_elems, "bits": bits,
                   "unit": config.unit, "backend": config.backend}
            for calls in _lowered:
                calls.append(dict(rec))

    backend = config.backend if config.all_thread else "scalar"
    if config.unit == "warp":
        return ops.decode(dev, codec=codec, width=width,
                          chunk_elems=chunk_elems, backend=backend,
                          interpret=config.interpret, bits=bits,
                          epilogue=epilogue, tune=tune)
    # "block": fixed pool of n_units streams; serial over chunk batches.
    n_chunks = dev["comp"].shape[0]
    nu = min(config.n_units, n_chunks)
    n_serial = (n_chunks + nu - 1) // nu
    pad = n_serial * nu - n_chunks

    def pad0(x):
        # shared tables (e.g. bitpack bits) and scalar epilogue
        # operands replicate across serial batches unchanged
        if x.ndim == 0 or x.shape[0] != n_chunks:
            return x
        return jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))

    devp = {k: pad0(v) for k, v in dev.items()}
    # out_lens of padding rows are 0 -> decode loops exit immediately.
    # Only per-chunk tables are scanned over; shared tables / scalar
    # epilogue operands have no n_chunks leading dim and must replicate
    # to every serial batch via closure (lax.scan requires every
    # scanned leaf to share the leading dim).
    scanned = {k: v.reshape((n_serial, nu) + v.shape[1:])
               for k, v in devp.items()
               if v.ndim and v.shape[0] == n_serial * nu}
    shared = {k: v for k, v in devp.items() if k not in scanned}

    def step(carry, batch):
        out = ops.decode({**batch, **shared}, codec=codec, width=width,
                         chunk_elems=chunk_elems, backend=backend,
                         interpret=config.interpret, bits=bits,
                         epilogue=epilogue, tune=tune)
        return carry, out

    _, outs = jax.lax.scan(step, 0, scanned)
    out = outs.reshape((n_serial * nu,) + outs.shape[2:])
    return out[:n_chunks]


# --------------------------------------------------------------------------
# jitted executors (lazy so this module stays importable pre-jax-init)
# --------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _decode_scatter_fn():
    """The jitted decode→scatter→place kernel for one fused group.

    One jit computation per (engine config, group statics, per-blob layout
    meta): the fused decode dispatch, every blob's row-range scatter, the
    optional epilogue, and each blob's sharding placement all trace
    together — executing the compiled function with pre-staged inputs
    performs zero host transfers in either direction, which is what lets
    ``execute_device`` run under ``transfers.no_host_transfers()``.
    """
    import jax

    @functools.partial(jax.jit, static_argnames=(
        "cfg", "codec", "width", "chunk_elems", "bits", "epilogue", "meta",
        "tune"))
    def decode_scatter(dev, scatter, *, cfg, codec, width, chunk_elems,
                       bits, epilogue, meta, tune):
        # tune is resolved by the caller OUTSIDE this trace and rides in as
        # a static arg: a swapped tuning table changes the jit key instead
        # of silently reusing a compilation built with the old knobs
        table = dispatch(dev, config=cfg, codec=codec, width=width,
                         chunk_elems=chunk_elems, bits=bits,
                         epilogue=epilogue, tune=tune)
        return _scatter_place(table, scatter, meta)

    return decode_scatter


def as_shard_list(out_shardings, n: int, what: str = "items"):
    """Normalize an ``out_shardings`` argument (None / one sharding / a
    per-item sequence with None holes) to a list of length ``n`` or None."""
    if out_shardings is None:
        return None
    if isinstance(out_shardings, (list, tuple)):
        if len(out_shardings) != n:
            raise ValueError(
                f"{len(out_shardings)} out_shardings for {n} {what}")
        return list(out_shardings)
    return [out_shardings] * n


def placeable(shape, sharding) -> bool:
    """Whether ``shape`` can be committed under ``sharding``.

    jax requires every sharded dim to divide evenly by its mesh-axes
    product; the place stage skips the commit (leaving the decoded output
    where the executor put it) when the shape cannot satisfy the spec —
    e.g. a ragged tail shard — instead of failing the whole decode.
    """
    spec = getattr(sharding, "spec", None)
    if spec is None:
        return True        # SingleDeviceSharding and friends
    if len(spec) > len(shape):
        return False
    for dim, part in zip(shape, spec):
        if part is None:
            continue
        axes = part if isinstance(part, tuple) else (part,)
        k = 1
        for a in axes:
            k *= int(sharding.mesh.shape[a])
        if dim % k:
            return False
    return True


def _scatter_place(table, scatter, meta):
    """Stages 4–6 for one decoded group table: reassemble every blob's row
    range, then commit it under its requested placement (if any)."""
    import jax

    outs = []
    for (row0, nc, total, odt, oshape, transformed, place), idx in zip(
            meta, scatter):
        out = fmt.reassemble_rows_device(
            table, row0=row0, num_chunks=nc, total_elems=total,
            orig_dtype=odt, orig_shape=oshape, indices=idx,
            transformed=transformed)
        if place is not None and placeable(out.shape, place):
            out = jax.lax.with_sharding_constraint(out, place)
        outs.append(out)
    return outs


@functools.lru_cache(maxsize=None)
def _sharded_decode_fn():
    """The jitted mesh-sharded decode→scatter→place kernel for one group.

    The fused table rides in row-sharded over ``axis`` (per-device uniform
    padding happened at stage time), ``shard_map`` runs the SAME
    :func:`dispatch` lowering shard-locally — D independent decoders, each
    decoding only the rows it owns — and the per-blob outputs are placed
    under their requested ``NamedSharding`` before they ever exist
    anywhere else.
    """
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    @functools.partial(jax.jit, static_argnames=(
        "cfg", "codec", "width", "chunk_elems", "bits", "epilogue", "meta",
        "mesh", "axis", "perchunk", "tune"))
    def decode_sharded(dev, scatter, *, cfg, codec, width, chunk_elems,
                       bits, epilogue, meta, mesh, axis, perchunk, tune):
        in_specs = ({k: P(axis, *([None] * (v.ndim - 1))) if k in perchunk
                     else P(*([None] * v.ndim))
                     for k, v in dev.items()},)

        def local(d):
            return dispatch(d, config=cfg, codec=codec, width=width,
                            chunk_elems=chunk_elems, bits=bits,
                            epilogue=epilogue, tune=tune)

        table = shard_map(local, mesh=mesh, in_specs=in_specs,
                          out_specs=P(axis, None), check_rep=False)(dev)
        return _scatter_place(table, scatter, meta)

    return decode_sharded


def _operand_cache_key(operands: Dict[str, Any]) -> tuple:
    """Staging-cache key for an epilogue-operand dict.

    Host values key by CONTENT digest (two equal-content dicts — even
    distinct objects built per call — share one staged upload).  Values
    already on device key by identity: hashing them would force an
    implicit device→host materialization that bypasses the ``to_host``
    funnel and trips ``jax.transfer_guard`` on real accelerators; the
    cache entry keeps a strong reference so the id stays valid.
    """
    import jax

    parts = []
    for k in sorted(operands):
        v = operands[k]
        if isinstance(v, jax.Array):
            parts.append((k, "dev", id(v)))
        else:
            a = np.asarray(v)
            h = hashlib.blake2b(digest_size=16)
            h.update(f"{a.dtype}|{a.shape}".encode())
            h.update(a.tobytes())
            parts.append((k, "host", h.hexdigest()))
    return tuple(parts)


def gather_member_tables(dev: Dict[str, Any], axis_name: str, *,
                         codec: Optional[str] = None,
                         shared: Sequence[str] = (),
                         row_counts=None) -> Dict[str, Any]:
    """Collective-plane stage: all-gather per-member chunk tables into ONE
    fused table, inside ``shard_map``.

    Each mesh member holds a device-built wire table (the ``dev`` pytree a
    :func:`dispatch` call consumes) describing its locally-encoded chunk
    rows.  This gathers every per-chunk leaf over ``axis_name`` and
    flattens the member axis into the chunk axis — member m's rows land at
    ``[m*n_chunks, (m+1)*n_chunks)`` — so ONE dispatch decodes every
    member's compressed bytes shard-locally after the all-gather moved only
    wire bytes.  Shared tables (the codec's ``shared_extras``, e.g.
    ``bitpack_bits``) and scalar operands replicate untouched: they are
    identical across members by wire-format construction.

    ``row_counts``: optional per-member scalar (int32) of VALID chunk rows
    for *ragged* member tables — members that padded their table to a
    common static height contribute ``row_counts`` real rows each; the
    gathered table's padding rows get ``out_lens``/``comp_lens`` zeroed so
    downstream masking (and length-honouring decode bodies) treat them as
    absent.
    """
    import jax.numpy as jnp
    from jax import lax

    shared = set(shared)
    if codec is not None:
        from repro.core import registry
        shared |= set(registry.get(codec).shared_extras)
    n_chunks = dev["out_lens"].shape[0]
    out = {}
    for k, v in dev.items():
        nd = getattr(v, "ndim", 0)
        if k in shared or nd < 1 or v.shape[0] != n_chunks:
            out[k] = v
            continue
        g = lax.all_gather(v, axis_name)              # (n_members, nc, ...)
        out[k] = g.reshape((-1,) + tuple(v.shape[1:]))
    if row_counts is not None:
        counts = lax.all_gather(row_counts, axis_name).reshape(-1)
        n_members = counts.shape[0]
        flat = jnp.arange(n_members * n_chunks, dtype=jnp.int32)
        valid = (flat % n_chunks) < counts[flat // n_chunks]
        out["out_lens"] = jnp.where(valid, out["out_lens"], 0)
        out["comp_lens"] = jnp.where(valid, out["comp_lens"], 0)
    return out


# --------------------------------------------------------------------------
# the IR
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlanGroup:
    """One fused dispatch: the merged chunk table for one group key."""

    key: tuple                    # (codec, width, chunk_elems, bits)
    blob_ids: Tuple[int, ...]     # positions in the input blob list
    row_offsets: Tuple[int, ...]  # first chunk row of each blob in `merged`
    merged: fmt.CompressedBlob
    # member blob refs (aligned with blob_ids), for the lazy scatter below
    members: Tuple[fmt.CompressedBlob, ...] = dataclasses.field(
        default=(), repr=False, compare=False)
    _scatter: Optional[tuple] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def scatter(self) -> Tuple[Optional[np.ndarray], ...]:
        """Per-blob device scatter (aligned with blob_ids): the flat gather
        from ``format.reassemble_indices``, or None when the blob's rows
        are contiguous and reshape+trim suffices (the standard layout).
        Computed lazily — callers that reassemble by row range themselves
        (the service window loop) never pay the O(total_elems) index
        build."""
        if self._scatter is None:
            object.__setattr__(self, "_scatter", tuple(
                fmt.reassemble_indices(b) for b in self.members))
        return self._scatter

    @property
    def num_chunks(self) -> int:
        return self.merged.num_chunks


@dataclasses.dataclass
class DecodePlan:
    """The lowered decode pipeline for one list of blobs.

    ``build`` is the parse/group stage; the ``execute*`` methods run the
    remaining stages on a single device, a caller-chosen device, or a
    device mesh.  Every entry path in the repo — ``api.decompress_many``,
    ``engine.decompress*``, ``batch.BatchPlan`` (an alias of this class),
    and the ``DecompressionService`` window loop — lowers to this IR.
    """

    blobs: List[fmt.CompressedBlob]
    groups: List[PlanGroup]
    # staged device inputs, lazily filled by stage(): group index -> device
    # pytree (placement key None = default device); plus staged per-blob
    # scatter index tables.
    _staged: Dict[Any, Dict[int, Any]] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    _staged_scatter: Dict[Any, Dict[int, Any]] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    # content-keyed bounded LRU of staged epilogue-operand dicts, entries
    # (staged dict, strong ref to the originals): repeat calls with
    # equal-content operands (even via distinct dict objects, or
    # alternating between several dicts) perform no host→device transfer.
    _staged_operands: "collections.OrderedDict[tuple, tuple]" = \
        dataclasses.field(default_factory=collections.OrderedDict,
                          repr=False, compare=False)
    # identity fast path in front of the content LRU: the steady-state
    # consumer passing the SAME operands dict every step skips hashing
    # entirely (the ref here keeps the dict's id valid).
    _last_operands: Optional[tuple] = dataclasses.field(
        default=None, repr=False, compare=False)

    # ------------------------------------------------------ parse / group

    @classmethod
    def build(cls, blobs: Sequence[fmt.CompressedBlob], *,
              bucket: bool = False,
              bucket_floor: Optional[int] = None) -> "DecodePlan":
        """Parse/group stage: one ``PlanGroup`` per distinct group key.

        ``bucket=True`` pads each merged table to pow2 row/column buckets
        (``format.pad_table_to_bucket``) so a long-lived caller (the
        serving window loop) hits the jit cache across differently-sized
        batches.  Padding rows trail the real rows, so per-blob row ranges
        are unaffected.  ``bucket_floor`` overrides the minimum column
        bucket; by default ``pad_table_to_bucket`` resolves it from the
        tuned-defaults table (``core.tuning``), falling back to 128.
        """
        blobs = list(blobs)
        by_key: Dict[tuple, List[int]] = {}
        for i, b in enumerate(blobs):
            by_key.setdefault(fmt.group_key(b), []).append(i)
        groups = []
        for key, ids in by_key.items():   # insertion order = first occurrence
            offsets, row = [], 0
            for i in ids:
                offsets.append(row)
                row += blobs[i].num_chunks
            merged = fmt.concat_blobs([blobs[i] for i in ids])
            if bucket:
                merged = fmt.pad_table_to_bucket(merged,
                                                 cols_floor=bucket_floor)
            groups.append(PlanGroup(
                key=key, blob_ids=tuple(ids), row_offsets=tuple(offsets),
                merged=merged, members=tuple(blobs[i] for i in ids)))
        return cls(blobs=blobs, groups=groups)

    @property
    def num_dispatches(self) -> int:
        return len(self.groups)

    @property
    def num_chunks(self) -> int:
        return sum(g.num_chunks for g in self.groups)

    # -------------------------------------------------------------- stage

    def stage(self, placement=None) -> "DecodePlan":
        """Upload every group's fused table and scatter index tables to the
        device, once.  ``placement``: optional ``jax.Device`` or
        ``jax.sharding.Sharding`` (the service's round-robin device
        assignment stages per device).  After staging, the execute paths
        perform no host→device transfers — the decode→consume path can run
        under ``transfers.no_host_transfers()``."""
        staged = self._staged.setdefault(placement, {})
        scat = self._staged_scatter.setdefault(placement, {})
        for gi, g in enumerate(self.groups):
            if gi not in staged:
                staged[gi] = ops.table_inputs(g.merged, placement)[0]
            if gi not in scat:
                scat[gi] = tuple(
                    None if s is None else transfers.to_device(s, placement)
                    for s in g.scatter)
        return self

    def stage_sharded(self, mesh, axis: str) -> "DecodePlan":
        """Stage for the mesh executor: each group's table is padded to a
        multiple of the axis size with zero-length chunks (per-device
        uniform work), uploaded row-sharded over ``axis``; shared tables
        and scatter indices replicate."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        key = (mesh, axis)
        staged = self._staged.setdefault(key, {})
        scat = self._staged_scatter.setdefault(key, {})
        ndev = int(mesh.shape[axis])
        for gi, g in enumerate(self.groups):
            if gi in staged:
                continue
            n = g.merged.num_chunks
            padded = fmt.pad_table_rows(g.merged, -(-n // ndev) * ndev)
            dev_np = padded.to_device()
            n_pad = padded.num_chunks
            # per-chunk leaves shard over the axis; group-wide shared
            # tables replicate.  Consult the codec's shared_extras — a
            # shared table whose length happens to equal the padded chunk
            # count must NOT be row-split (same disambiguation
            # format.pad_table_rows / concat_blobs use).
            from repro.core import registry
            shared = set(registry.get(g.merged.codec).shared_extras)
            perchunk = frozenset(
                k for k, v in dev_np.items()
                if k not in shared
                and getattr(v, "ndim", 0) >= 1 and v.shape[0] == n_pad)
            dev = {}
            for k, v in dev_np.items():
                nd = getattr(v, "ndim", 0)
                spec = (P(axis, *([None] * (nd - 1))) if k in perchunk
                        else P(*([None] * nd)))
                dev[k] = transfers.to_device(v, NamedSharding(mesh, spec))
            staged[gi] = (dev, perchunk)
            scat[gi] = tuple(
                None if s is None
                else transfers.to_device(s, NamedSharding(mesh, P(None)))
                for s in g.scatter)
        return self

    def _stage_operands(self, operands: Optional[Dict[str, Any]],
                        placement=None) -> Dict[str, Any]:
        """Digest-keyed bounded staging cache for epilogue operands.

        Keyed by content (not dict identity): a consumer alternating
        between two operand dicts — or rebuilding an equal dict per call —
        re-uploads nothing.  Bounded to ``OPERAND_CACHE_SLOTS`` entries
        (LRU) so device memory cannot grow without limit."""
        if not operands:
            return {}
        last = self._last_operands
        if (last is not None and last[0] is operands
                and last[1] == placement):
            return last[2]                  # O(1): same dict object again
        key = (_operand_cache_key(operands), placement)
        cached = self._staged_operands.get(key)
        if cached is not None:
            self._staged_operands.move_to_end(key)
            staged = cached[0]
        else:
            staged = {k: transfers.to_device(v, placement)
                      for k, v in operands.items()}
            # keep the originals alive alongside the staged dict: identity
            # key components (device-array operands) must not recycle ids
            self._staged_operands[key] = (staged, dict(operands))
            while len(self._staged_operands) > OPERAND_CACHE_SLOTS:
                self._staged_operands.popitem(last=False)
        self._last_operands = (operands, placement, staged)
        return staged

    # ------------------------------------------------- dispatch + execute

    def decode_group_device(self, gi: int, engine=None, *, device=None,
                            epilogue=None):
        """Stage + dispatch one group; returns the raw decoded
        ``(num_chunks, chunk_elems)`` device matrix (no reassembly).

        ``device``: optional ``jax.Device`` to stage and decode on — the
        service's per-window round-robin group→device assignment.  Callers
        owning the blob→row mapping (the service window loop) scatter the
        result themselves.
        """
        engine = _default_engine(engine)
        self_staged = self._staged.setdefault(device, {})
        if gi not in self_staged:
            self_staged[gi] = ops.table_inputs(self.groups[gi].merged,
                                               device)[0]
        codec, width, chunk_elems, bits = self.groups[gi].key
        from repro.core import tuning
        return dispatch(self_staged[gi], config=engine.config, codec=codec,
                        width=width, chunk_elems=chunk_elems, bits=bits,
                        epilogue=epilogue,
                        tune=tuning.kernel_tune(codec, width,
                                                engine.config.tune))

    def _blob_meta(self, g: PlanGroup, transformed: bool,
                   places: Optional[List]) -> tuple:
        return tuple(
            (row0, self.blobs[bid].num_chunks, self.blobs[bid].total_elems,
             self.blobs[bid].orig_dtype, tuple(self.blobs[bid].orig_shape),
             transformed, None if places is None else places[bid])
            for bid, row0 in zip(g.blob_ids, g.row_offsets))

    @staticmethod
    def _place_list(out_shardings, n: int) -> Optional[List]:
        return as_shard_list(out_shardings, n, what="blobs")

    def execute(self, engine=None) -> List[np.ndarray]:
        """Host executor: one dispatch per group, one sanctioned d2h
        materialization per group table, scatter back in input order."""
        engine = _default_engine(engine)
        outs: List[Optional[np.ndarray]] = [None] * len(self.blobs)
        for g in self.groups:
            table = engine.decompress_table(g.merged)
            for bid, row0 in zip(g.blob_ids, g.row_offsets):
                blob = self.blobs[bid]
                # copy: reassemble() of a contiguous slice is a view into the
                # whole group table — returning it would pin that table for
                # as long as any single output lives.
                rows = table[row0:row0 + blob.num_chunks].copy()
                outs[bid] = fmt.reassemble(blob, rows)
        return outs  # type: ignore[return-value]

    def execute_device(self, engine=None, *, epilogue=None,
                       epilogue_operands: Optional[Dict[str, Any]] = None,
                       out_shardings=None) -> List[Any]:
        """Device executor: one dispatch per group; per-blob scatter, the
        optional fused ``epilogue``, and each output's placement all on
        device.  Returns jax arrays in input order; with the plan
        pre-``stage()``d there are zero host transfers in either direction.

        ``epilogue_operands``: arrays for the epilogue's ``scale_key`` /
        ``zero_key`` device-pytree entries — staged through the bounded
        digest-keyed cache, so steady-state repeat calls (same content, any
        dict identity) perform no host→device transfer.
        ``out_shardings``: one ``Sharding`` (or a per-blob list) the
        outputs are committed under — the plan's *place* stage.
        """
        engine = _default_engine(engine)
        self.stage()
        ops_extra = self._stage_operands(epilogue_operands)
        places = self._place_list(out_shardings, len(self.blobs))
        outs: List[Any] = [None] * len(self.blobs)
        decode_scatter = _decode_scatter_fn()
        from repro.core import tuning
        for gi, g in enumerate(self.groups):
            dev = self._staged[None][gi]
            if ops_extra:
                dev = {**dev, **ops_extra}
            codec, width, chunk_elems, bits = g.key
            group_outs = decode_scatter(
                dev, list(self._staged_scatter[None][gi]),
                cfg=engine.config, codec=codec, width=width,
                chunk_elems=chunk_elems, bits=bits, epilogue=epilogue,
                meta=self._blob_meta(g, epilogue is not None, places),
                tune=tuning.kernel_tune(codec, width, engine.config.tune))
            for bid, out in zip(g.blob_ids, group_outs):
                outs[bid] = out
        return outs

    def execute_sharded(self, mesh, *, axis: Optional[str] = None,
                        engine=None, epilogue=None,
                        epilogue_operands: Optional[Dict[str, Any]] = None,
                        out_shardings=None) -> List[Any]:
        """Mesh executor: every group's chunk rows are partitioned across
        ``mesh``'s ``axis`` and decoded shard-locally (``shard_map`` over
        the same :func:`dispatch` lowering — D devices, D independent
        decoders, no all-gather), and each blob's output is born under its
        requested ``NamedSharding``.  Bit-exact vs :meth:`execute`.
        """
        from jax.sharding import NamedSharding, PartitionSpec as P

        engine = _default_engine(engine)
        if axis is None:
            from repro.distributed import sharding as shd
            axis = shd.decode_axis(mesh)
        self.stage_sharded(mesh, axis)
        ops_extra = self._stage_operands(
            epilogue_operands, NamedSharding(mesh, P()))
        places = self._place_list(out_shardings, len(self.blobs))
        outs: List[Any] = [None] * len(self.blobs)
        decode_sharded = _sharded_decode_fn()
        from repro.core import tuning
        for gi, g in enumerate(self.groups):
            dev, perchunk = self._staged[(mesh, axis)][gi]
            if ops_extra:
                dev = {**dev, **ops_extra}
            codec, width, chunk_elems, bits = g.key
            group_outs = decode_sharded(
                dev, list(self._staged_scatter[(mesh, axis)][gi]),
                cfg=engine.config, codec=codec, width=width,
                chunk_elems=chunk_elems, bits=bits, epilogue=epilogue,
                meta=self._blob_meta(g, epilogue is not None, places),
                mesh=mesh, axis=axis, perchunk=perchunk,
                tune=tuning.kernel_tune(codec, width, engine.config.tune))
            for bid, out in zip(g.blob_ids, group_outs):
                outs[bid] = out
        return outs


def decompress_blobs(blobs: Sequence[fmt.CompressedBlob], engine=None,
                     device_out: bool = False, epilogue=None, *,
                     mesh=None, axis: Optional[str] = None,
                     out_shardings=None) -> List:
    """Batched decompress over many blobs through one :class:`DecodePlan`:
    one dispatch per (codec, width, chunk_elems, bits) group, outputs in
    input order.  ``device_out=True`` keeps every output on device;
    ``mesh`` decodes each group's rows across the mesh's devices
    (``execute_sharded``); ``out_shardings`` places outputs (device paths
    only)."""
    if not blobs:
        return []
    plan = DecodePlan.build(blobs)
    if mesh is not None:
        return plan.execute_sharded(mesh, axis=axis, engine=engine,
                                    epilogue=epilogue,
                                    out_shardings=out_shardings)
    if device_out:
        return plan.execute_device(engine, epilogue=epilogue,
                                   out_shardings=out_shardings)
    if epilogue is not None:
        raise ValueError("epilogue requires device_out=True: a fused "
                         "epilogue's output has no host reassembly path")
    return plan.execute(engine)
