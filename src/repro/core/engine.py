"""CodagEngine — GPU-resource-provisioning strategies, transplanted.

The paper's central claim is about *provisioning*: how many independent
decompression streams the hardware scheduler can interleave.  The engine
exposes that axis directly:

  unit="warp"   (CODAG)  one chunk per independent stream — vmap across all
                chunks / Pallas grid cell per chunk.  Maximal stream count.
  unit="block"  (RAPIDS baseline, Fig. 1a) a fixed pool of ``n_units``
                decompression units, each *serially* looping over its share
                of chunks (lax.scan over serial batches of a vmapped pool).
                This reproduces the baseline's few-streams provisioning.

  all_thread=True   (CODAG §IV-D) vectorized two-phase decode — every lane
                participates in decode+write.
  all_thread=False  (§V-E ablation) single-thread decoding: one element per
                loop step.

  backend="pallas"  the TPU kernels (interpret=True on CPU);
  backend="xla"     same decode bodies compiled by XLA (production CPU path).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import format as fmt
from repro.core import transfers
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    unit: str = "warp"          # "warp" (CODAG) | "block" (RAPIDS-like)
    n_units: int = 8            # decompression-unit pool size for "block"
    all_thread: bool = True     # False = §V-E single-thread decoding
    backend: str = "xla"        # "xla" | "pallas" | "oracle"
    interpret: bool = True      # pallas interpret mode (CPU validation)


class CodagEngine:
    def __init__(self, config: EngineConfig = EngineConfig()):
        self.config = config

    def _backend(self) -> str:
        c = self.config
        if not c.all_thread:
            return "scalar"
        return c.backend

    def decompress_chunks(self, dev: Dict[str, Any], *, codec: str,
                          width: int, chunk_elems: int,
                          bits: int = 0, epilogue=None) -> jnp.ndarray:
        """Decode to (num_chunks, chunk_elems); jit-compatible.

        ``epilogue``: optional ``kernels.harness.Epilogue`` fused into the
        dispatch (cast/widen/dequant before the matrix reaches a consumer).
        """
        c = self.config
        backend = self._backend()
        if c.unit == "warp":
            return ops.decode(dev, codec=codec, width=width,
                              chunk_elems=chunk_elems, backend=backend,
                              interpret=c.interpret, bits=bits,
                              epilogue=epilogue)
        # "block": fixed pool of n_units streams; serial over chunk batches.
        n_chunks = dev["comp"].shape[0]
        nu = min(c.n_units, n_chunks)
        n_serial = (n_chunks + nu - 1) // nu
        pad = n_serial * nu - n_chunks

        def pad0(x):
            # shared tables (e.g. bitpack bits) and scalar epilogue
            # operands replicate across serial batches unchanged
            if x.ndim == 0 or x.shape[0] != n_chunks:
                return x
            return jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))

        devp = {k: pad0(v) for k, v in dev.items()}
        # out_lens of padding rows are 0 -> decode loops exit immediately.
        # Only per-chunk tables are scanned over; shared tables / scalar
        # epilogue operands have no n_chunks leading dim and must replicate
        # to every serial batch via closure (lax.scan requires every
        # scanned leaf to share the leading dim).
        scanned = {k: v.reshape((n_serial, nu) + v.shape[1:])
                   for k, v in devp.items()
                   if v.ndim and v.shape[0] == n_serial * nu}
        shared = {k: v for k, v in devp.items() if k not in scanned}

        def step(carry, batch):
            out = ops.decode({**batch, **shared}, codec=codec, width=width,
                             chunk_elems=chunk_elems, backend=backend,
                             interpret=c.interpret, bits=bits,
                             epilogue=epilogue)
            return carry, out

        _, outs = jax.lax.scan(step, 0, scanned)
        out = outs.reshape((n_serial * nu, chunk_elems))
        return out[:n_chunks]

    def decompress_table_device(self, table: fmt.CompressedBlob,
                                epilogue=None) -> jnp.ndarray:
        """Decode a flat chunk table (a single blob or a multi-blob merge
        from ``format.concat_blobs``) with one dispatch, no reassembly; the
        raw (num_chunks, chunk_elems) matrix STAYS on device.  Callers
        owning a blob→row mapping scatter it back with
        ``format.reassemble_device``."""
        dev, bits = ops.table_inputs(table)
        return self.decompress_chunks(dev, codec=table.codec,
                                      width=table.width,
                                      chunk_elems=table.chunk_elems,
                                      bits=bits, epilogue=epilogue)

    def decompress_table(self, table: fmt.CompressedBlob) -> np.ndarray:
        """Host variant of :func:`decompress_table_device`: one dispatch,
        then one sanctioned device→host materialization."""
        return transfers.to_host(self.decompress_table_device(table))

    def decompress(self, blob: fmt.CompressedBlob) -> np.ndarray:
        """Host convenience: full round trip back to the original ndarray."""
        return fmt.reassemble(blob, self.decompress_table(blob))

    def decompress_device(self, blob: fmt.CompressedBlob,
                          epilogue=None) -> jnp.ndarray:
        """Device convenience: full round trip to a device-resident array —
        decode + reassembly (and any fused epilogue) without a host visit."""
        return fmt.reassemble_device(
            blob, self.decompress_table_device(blob, epilogue=epilogue),
            transformed=epilogue is not None)
