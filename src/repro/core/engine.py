"""CodagEngine — GPU-resource-provisioning strategies, transplanted.

The paper's central claim is about *provisioning*: how many independent
decompression streams the hardware scheduler can interleave.  The engine
exposes that axis directly:

  unit="warp"   (CODAG)  one chunk per independent stream — vmap across all
                chunks / Pallas grid cell per chunk.  Maximal stream count.
  unit="block"  (RAPIDS baseline, Fig. 1a) a fixed pool of ``n_units``
                decompression units, each *serially* looping over its share
                of chunks (lax.scan over serial batches of a vmapped pool).
                This reproduces the baseline's few-streams provisioning.

  all_thread=True   (CODAG §IV-D) vectorized two-phase decode — every lane
                participates in decode+write.
  all_thread=False  (§V-E ablation) single-thread decoding: one element per
                loop step.

  backend="pallas"  the TPU kernels (interpret=True on CPU);
  backend="xla"     same decode bodies compiled by XLA (production CPU path).

The engine is a *configuration* wrapper: every decode it issues lowers
through the unified plan IR (``core.plan.dispatch`` is the one
``ops.decode`` site; the convenience round trips build one-blob
``DecodePlan``s), so the engine, the batch scheduler, the public API, and
the serving loop all execute the same pipeline.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax.numpy as jnp
import numpy as np

from repro.core import format as fmt
from repro.core import plan as plan_mod
from repro.core import transfers
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    unit: str = "warp"          # "warp" (CODAG) | "block" (RAPIDS-like)
    n_units: int = 8            # decompression-unit pool size for "block"
    all_thread: bool = True     # False = §V-E single-thread decoding
    backend: str = "xla"        # "xla" | "pallas" | "oracle"
    interpret: bool = True      # pallas interpret mode (CPU validation)
    # explicit kernel-knob overrides ((name, value), ...) — merged over the
    # tuned-defaults table per dispatch (explicit wins; ``core.tuning``)
    tune: tuple = ()


class CodagEngine:
    def __init__(self, config: EngineConfig = EngineConfig()):
        self.config = config

    def decompress_chunks(self, dev: Dict[str, Any], *, codec: str,
                          width: int, chunk_elems: int,
                          bits: int = 0, epilogue=None) -> jnp.ndarray:
        """Decode to (num_chunks, chunk_elems); jit-compatible.

        Lowers straight to the plan IR's dispatch stage (the repo's one
        ``ops.decode`` call site) under this engine's provisioning config.
        ``epilogue``: optional ``kernels.harness.Epilogue`` fused into the
        dispatch (cast/widen/dequant before the matrix reaches a consumer).
        """
        return plan_mod.dispatch(dev, config=self.config, codec=codec,
                                 width=width, chunk_elems=chunk_elems,
                                 bits=bits, epilogue=epilogue)

    def decompress_table_device(self, table: fmt.CompressedBlob,
                                epilogue=None) -> jnp.ndarray:
        """Decode a flat chunk table (a single blob or a multi-blob merge
        from ``format.concat_blobs``) with one dispatch, no reassembly; the
        raw (num_chunks, chunk_elems) matrix STAYS on device.  Callers
        owning a blob→row mapping scatter it back with
        ``format.reassemble_device``."""
        dev, bits = ops.table_inputs(table)
        return self.decompress_chunks(dev, codec=table.codec,
                                      width=table.width,
                                      chunk_elems=table.chunk_elems,
                                      bits=bits, epilogue=epilogue)

    def decompress_table(self, table: fmt.CompressedBlob) -> np.ndarray:
        """Host variant of :func:`decompress_table_device`: one dispatch,
        then one sanctioned device→host materialization."""
        return transfers.to_host(self.decompress_table_device(table))

    def decompress(self, blob: fmt.CompressedBlob) -> np.ndarray:
        """Host convenience: full round trip back to the original ndarray
        (a one-blob DecodePlan, executed on the host path)."""
        return plan_mod.DecodePlan.build([blob]).execute(self)[0]

    def decompress_device(self, blob: fmt.CompressedBlob,
                          epilogue=None) -> jnp.ndarray:
        """Device convenience: full round trip to a device-resident array —
        a one-blob DecodePlan on the device path (decode + reassembly and
        any fused epilogue, no host visit)."""
        return plan_mod.DecodePlan.build([blob]).execute_device(
            self, epilogue=epilogue)[0]
