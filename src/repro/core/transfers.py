"""Host-transfer accounting: the device→host funnel + transfer guards.

CODAG's throughput argument is that decompression is gated by *moving the
uncompressed output*, not by decoding.  A decode path that round-trips
through the host pays that output-bandwidth tax twice (device→host, then
host→device at the consumer) plus a blocking sync per materialization.  To
keep the device-resident paths honest, every intentional device→host
materialization in this repo goes through ONE funnel — :func:`to_host` —
so tests and benchmarks can count transfers, and a guard can turn any
reintroduced host round-trip into a loud failure.

Two layers of enforcement:

* :func:`no_host_transfers` raises on any :func:`to_host` call from the
  current thread AND enters ``jax.transfer_guard("disallow")``, which on a
  real accelerator additionally rejects implicit transfers that bypass the
  funnel (``np.asarray(device_array)``, unstaged operands).  On the CPU
  backend jax's guard is inert (host == device, transfers are zero-copy),
  which is exactly why the funnel exists: the CI ``no-host-transfer`` gate
  stays meaningful on CPU-only runners.
* :func:`count_host_transfers` counts funnel crossings (from every thread —
  the DecompressionService materializes on its worker thread) without
  forbidding them, for benchmarks that report host-round-trip traffic.

The mirror direction has a funnel too: :func:`to_device` is the ONE
sanctioned host→device staging path (plan staging, epilogue-operand
uploads, per-device round-robin placement).  It is never forbidden —
staging is how data legitimately reaches the device — but it is counted
(``h2d`` / ``h2d_bytes`` in the same counter dict), so a staging cache
regression (e.g. re-uploading epilogue operands every call) shows up as a
growing ``h2d`` count instead of silent PCIe traffic.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Iterator

import jax
import numpy as np

_tls = threading.local()          # per-thread disallow depth
_counters: list = []              # active counter dicts (all threads)
_counters_lock = threading.Lock()


def to_host(x) -> np.ndarray:
    """Materialize a device array on the host (the ONE sanctioned d2h path).

    Raises ``RuntimeError`` when called (on this thread) inside
    :func:`no_host_transfers`; otherwise records the transfer with every
    active :func:`count_host_transfers` context and returns a numpy array.
    """
    if getattr(_tls, "disallow", 0):
        raise RuntimeError(
            "device->host transfer inside no_host_transfers(): a "
            "device-resident decode path materialized on the host. "
            "Use device_out=True end to end (reassemble_device / "
            "combine_planes_device) or move this call outside the guard.")
    nbytes = int(getattr(x, "nbytes", 0))
    with _counters_lock:       # snapshot-free: fan-out under the lock (no
        for c in _counters:    # check-then-act window vs register/remove)
            c["d2h"] += 1
            c["bytes"] += nbytes
    return np.asarray(jax.device_get(x))


def to_device(x, placement=None):
    """Stage a host array on the device (the ONE sanctioned h2d path).

    ``placement``: optional ``jax.Device`` or ``jax.sharding.Sharding`` the
    result should live under (``None`` = default device).  Counted with
    every active :func:`count_host_transfers` context (``h2d`` /
    ``h2d_bytes``) so staging caches can be regression-tested; never
    forbidden — staging is how data legitimately reaches the device.
    Already-on-device inputs pass through ``device_put`` untouched (and
    uncounted when no placement change is requested).
    """
    import jax.numpy as jnp
    is_host = not isinstance(x, jax.Array)
    if is_host or placement is not None:
        nbytes = int(getattr(x, "nbytes", 0)) if is_host else 0
        with _counters_lock:
            for c in _counters:
                c["h2d"] += 1 if is_host else 0
                c["h2d_bytes"] += nbytes
        return jax.device_put(jnp.asarray(x) if not hasattr(x, "dtype")
                              else x, placement)
    return x


@contextlib.contextmanager
def no_host_transfers() -> Iterator[None]:
    """Forbid host materialization on this thread for the duration.

    Stacks ``jax.transfer_guard("disallow")`` (catches implicit transfers on
    real accelerators) on top of the :func:`to_host` funnel check (catches
    explicit materialization even on CPU, where jax's guard cannot).
    Reentrant; thread-local, so e.g. a DecompressionService worker serving
    *other* requests is unaffected.
    """
    prev = getattr(_tls, "disallow", 0)
    _tls.disallow = prev + 1
    try:
        with jax.transfer_guard("disallow"):
            yield
    finally:
        _tls.disallow = prev


@contextlib.contextmanager
def count_host_transfers() -> Iterator[Dict[str, int]]:
    """Count funnel crossings (all threads) while the context is open.
    Yields ``{"d2h": calls, "bytes": d2h bytes, "h2d": stagings,
    "h2d_bytes": staged bytes}``; contexts may nest or overlap — each
    active context sees every crossing."""
    c = {"d2h": 0, "bytes": 0, "h2d": 0, "h2d_bytes": 0}
    with _counters_lock:
        _counters.append(c)
    try:
        yield c
    finally:
        # remove by identity: two open contexts may hold equal-valued dicts
        # (list.remove compares by equality and would drop the wrong one)
        with _counters_lock:
            for i, cur in enumerate(_counters):
                if cur is c:
                    del _counters[i]
                    break
