"""Public compress/decompress API.

    from repro.core import api
    blob = api.compress(arr, "rle_v2")          # host-side encode
    out  = api.decompress(blob)                 # device decode, == arr

    cas  = api.compress_many(arrs, "rle_v2")    # list in, list out
    outs = api.decompress_many(cas)             # ONE dispatch per codec group

8-byte dtypes are plane-decomposed (lo/hi uint32 planes compressed as two
blobs) so RLE runs survive — see DESIGN.md §2 format notes.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Union

import numpy as np

from repro.core import encoders as enc
from repro.core import format as fmt
from repro.core import plan as plan_mod
from repro.core import registry
from repro.core.engine import CodagEngine, EngineConfig


@dataclasses.dataclass
class CompressedArray:
    """One logical array; 1 blob normally, 2 plane blobs for 8-byte dtypes."""
    blobs: list
    orig_dtype: str
    orig_shape: tuple

    @property
    def ratio(self) -> float:
        comp = sum(b.compressed_bytes for b in self.blobs)
        unc = sum(b.uncompressed_bytes for b in self.blobs)
        return comp / max(1, unc)

    @property
    def compressed_bytes(self) -> int:
        return sum(b.compressed_bytes for b in self.blobs)


def compress(arr: np.ndarray, codec: str,
             chunk_bytes: Optional[int] = None,
             bits: Optional[int] = None) -> CompressedArray:
    """Compress one array.  ``chunk_bytes=None`` resolves the tuned chunk
    size for this codec/width/device from ``core.tuning``'s committed
    defaults table, falling back to ``format.DEFAULT_CHUNK_BYTES``; an
    explicit value always wins (``encoders.compress`` resolution)."""
    if arr.dtype.itemsize == 8 and registry.get(codec).plane_decompose_64:
        # plane decomposition: lo/hi u32 planes keep runs intact
        as_u64 = arr.reshape(-1).view(np.uint64)
        lo = (as_u64 & np.uint64(0xFFFFFFFF)).astype(np.uint32)
        hi = (as_u64 >> np.uint64(32)).astype(np.uint32)
        return CompressedArray(
            blobs=[enc.compress(lo, codec, chunk_bytes),
                   enc.compress(hi, codec, chunk_bytes)],
            orig_dtype=str(arr.dtype), orig_shape=tuple(arr.shape))
    return CompressedArray(blobs=[enc.compress(arr, codec, chunk_bytes, bits=bits)],
                           orig_dtype=str(arr.dtype), orig_shape=tuple(arr.shape))


def _combine(ca: CompressedArray, outs: List[np.ndarray]) -> np.ndarray:
    return fmt.combine_planes(outs, ca.orig_dtype, ca.orig_shape)


def _combine_device(ca: CompressedArray, outs: List, transformed: bool):
    if transformed:
        # epilogue output: plane recombination over transformed values is
        # undefined — refuse rather than silently drop the hi plane
        if len(outs) != 1:
            raise ValueError(
                f"epilogue cannot be applied to a plane-decomposed "
                f"{ca.orig_dtype} array ({len(outs)} plane blobs): the "
                "transform runs per uint32 plane, so the 64-bit value "
                "cannot be recombined afterwards")
        return outs[0]
    return fmt.combine_planes_device(outs, ca.orig_dtype, ca.orig_shape)


def decompress(ca: CompressedArray,
               engine: Optional[CodagEngine] = None,
               device_out: bool = False):
    engine = engine or CodagEngine(EngineConfig())
    if device_out:
        return _combine_device(ca, [engine.decompress_device(b)
                                    for b in ca.blobs], transformed=False)
    return _combine(ca, [engine.decompress(b) for b in ca.blobs])


def compress_many(arrays: Sequence[np.ndarray],
                  codec: Union[str, Sequence[str]],
                  chunk_bytes: Optional[int] = None,
                  bits: Optional[int] = None) -> List[CompressedArray]:
    """Compress a list of arrays; ``codec`` may be one name or one per array.

    Encoding stays a host/offline concern (as in the paper); the point of the
    list form is that the resulting blobs land in the batched decode path.
    """
    codecs = [codec] * len(arrays) if isinstance(codec, str) else list(codec)
    if len(codecs) != len(arrays):
        raise ValueError(f"{len(codecs)} codecs for {len(arrays)} arrays")
    return [compress(a, c, chunk_bytes, bits=bits)
            for a, c in zip(arrays, codecs)]


def decompress_many(cas: Sequence[CompressedArray],
                    engine: Optional[CodagEngine] = None,
                    service=None, *, device_out: bool = False,
                    epilogue=None, epilogue_operands=None,
                    mesh=None, mesh_axis: Optional[str] = None,
                    out_shardings=None) -> List:
    """Batched decompress: every chunk of every array in one launch per
    (codec, width, chunk_elems, bits) group — the CODAG provisioning move.
    All paths lower to one ``core.plan.DecodePlan``.

    With no ``engine``, a host-out call routes through the process-wide
    ``server.default_service()`` (or an explicit ``service=``): all blobs
    enter ONE micro-batch window atomically — same one-dispatch-per-group
    accounting as the direct plan, plus the service's decoded-blob cache
    and coalescing with any other concurrently-submitted requests.  Passing
    an ``engine`` keeps the direct synchronous plan path (exact per-call
    dispatch control, custom engine configs).

    ``device_out=True`` returns device-resident jax arrays — decode,
    per-blob scatter, 64-bit plane recombination, and the optional fused
    ``epilogue`` (a ``kernels.harness.Epilogue``: cast / widen / dequant
    inside the decode dispatch) all happen on device with zero device→host
    syncs.  An explicit ``service=`` serves device views through its window
    machinery; otherwise the direct plan path runs (epilogues are
    plan-path only — a service window mixes tenants that may want
    different transforms).

    ``mesh`` (implies device out) decodes every group's chunk rows across
    the mesh's ``mesh_axis`` devices (``DecodePlan.execute_sharded``) —
    the multi-device provisioning move; ``out_shardings`` (one sharding or
    one per array, ``None`` entries allowed) commits each output under the
    requested ``NamedSharding`` — the plan's *place* stage — so results
    are born where the consumer wants them.

    Bit-exact vs. per-array ``decompress``; outputs follow input order.
    """
    if engine is not None and service is not None:
        raise ValueError("pass engine= OR service=, not both: the service "
                         "decodes on its own engine")
    device_out = device_out or mesh is not None
    if epilogue is not None and not device_out:
        raise ValueError("epilogue requires device_out=True: a fused "
                         "epilogue's output has no host reassembly path")
    if out_shardings is not None and not device_out:
        raise ValueError("out_shardings requires device_out=True (or "
                         "mesh=): host arrays have no device placement")
    if not cas:
        return []
    if service is not None or (engine is None and not device_out):
        if epilogue is not None:
            raise ValueError("epilogue is not supported on the service "
                             "path; pass engine= (or no engine) with "
                             "device_out=True")
        if mesh is not None or out_shardings is not None:
            raise ValueError("mesh/out_shardings are not supported on the "
                             "service path; pass engine= (or no engine) "
                             "for the direct plan executors")
        if service is None:
            from repro.core import server as server_mod
            service = server_mod.default_service()
        return service.decode_arrays(cas, device_out=device_out)
    flat: List[fmt.CompressedBlob] = []
    spans: List[tuple] = []   # (start, count) into flat, per array
    for ca in cas:
        spans.append((len(flat), len(ca.blobs)))
        flat.extend(ca.blobs)
    per_array = (plan_mod.as_shard_list(out_shardings, len(cas),
                                        what="arrays")
                 or [None] * len(cas))
    if device_out:
        plan = plan_mod.DecodePlan.build(flat)
        # single-blob arrays place inside the plan (born under their
        # sharding); plane-decomposed arrays place after recombination.
        blob_sh: List = [None] * len(flat)
        for (s, n), sh in zip(spans, per_array):
            if sh is not None and n == 1:
                blob_sh[s] = sh
        if mesh is not None:
            outs = plan.execute_sharded(
                mesh, axis=mesh_axis, engine=engine, epilogue=epilogue,
                epilogue_operands=epilogue_operands, out_shardings=blob_sh)
        else:
            outs = plan.execute_device(
                engine, epilogue=epilogue,
                epilogue_operands=epilogue_operands, out_shardings=blob_sh)
        results = []
        for ca, (s, n), sh in zip(cas, spans, per_array):
            out = _combine_device(ca, outs[s:s + n], epilogue is not None)
            if sh is not None and n > 1 and plan_mod.placeable(out.shape, sh):
                import jax
                out = jax.device_put(out, sh)
            results.append(out)
        return results
    outs = plan_mod.decompress_blobs(flat, engine)
    return [_combine(ca, outs[s:s + n]) for ca, (s, n) in zip(cas, spans)]
