"""input_stream / output_stream — the paper's Table I & II APIs in JAX.

The paper isolates all GPU memory-access optimization behind two stream
abstractions so codec authors only write the sequential decode loop:

  input_stream:  fetch_bits(n), peek_bits(n)            (Table I)
  output_stream: write_byte(b), write_run(init,len,d),
                 memcpy(offset,len)                     (Table II)

Here they are *functional*: each stream is a NamedTuple of arrays, every
operation returns the updated stream, and all of it traces under jit /
vmap / pallas.  On-demand reading (Alg. 1) maps to funnel-shifted loads
from a padded word buffer (the HBM->VMEM DMA performed by the enclosing
BlockSpec is TPU's cache-line-coalesced fetch); the overlap-safe memcpy
(Alg. 2, incl. the len>offset circular-window case) maps to a modulo-
indexed vector gather + masked blend.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

# --------------------------------------------------------------------------
# input_stream over a bit-packed uint32 word buffer (LSB-first)
# --------------------------------------------------------------------------


class BitStream(NamedTuple):
    words: jnp.ndarray   # (n_words,) uint32 — must be padded by >=2 words
    pos: jnp.ndarray     # () int32 absolute bit position


def bitstream(words: jnp.ndarray) -> BitStream:
    return BitStream(words=words, pos=jnp.int32(0))


def _funnel32(w0: jnp.ndarray, w1: jnp.ndarray, off: jnp.ndarray) -> jnp.ndarray:
    """32-bit funnel shift: bits [off, off+32) of the 64-bit pair (w0, w1)."""
    lo = jnp.right_shift(w0, off.astype(jnp.uint32))
    sh = (jnp.uint32(32) - off.astype(jnp.uint32)) & jnp.uint32(31)
    hi = jnp.where(off > 0, jnp.left_shift(w1, sh), jnp.uint32(0))
    return lo | hi


def peek_bits(s: BitStream, n) -> jnp.ndarray:
    """Peek the next ``n`` (<=16, static or dynamic) bits. Table I."""
    w = s.pos >> 5
    off = (s.pos & 31).astype(jnp.uint32)
    w0 = jnp.take(s.words, w, mode="clip")
    w1 = jnp.take(s.words, w + 1, mode="clip")
    v = _funnel32(w0, w1, off)
    mask = (jnp.uint32(1) << jnp.uint32(n)) - jnp.uint32(1)
    return v & mask


def fetch_bits(s: BitStream, n):
    """Fetch (consume) the next ``n`` bits. Table I. Returns (value, stream)."""
    v = peek_bits(s, n)
    return v, s._replace(pos=s.pos + jnp.int32(n))


def skip_bits(s: BitStream, n) -> BitStream:
    return s._replace(pos=s.pos + jnp.int32(n))


# --------------------------------------------------------------------------
# byte-granular input_stream (RLE codecs are byte-aligned)
# --------------------------------------------------------------------------


class ByteStream(NamedTuple):
    data: jnp.ndarray    # (n_bytes,) uint8 — padded by >=4 bytes
    pos: jnp.ndarray     # () int32 byte position


def bytestream(data: jnp.ndarray) -> ByteStream:
    return ByteStream(data=data, pos=jnp.int32(0))


def read_byte_at(data: jnp.ndarray, pos) -> jnp.ndarray:
    return jnp.take(data, pos, mode="clip").astype(jnp.int32)


def read_value_at(data: jnp.ndarray, pos, width: int) -> jnp.ndarray:
    """Assemble a little-endian fixed-width value (width in {1,2,4}) as u32."""
    b = [jnp.take(data, pos + i, mode="clip").astype(jnp.uint32) for i in range(width)]
    v = b[0]
    for i in range(1, width):
        v = v | (b[i] << jnp.uint32(8 * i))
    return v


def gather_values(data: jnp.ndarray, byte_offs, width: int) -> jnp.ndarray:
    """Vector-assemble little-endian fixed-width values at byte offsets.

    The shared multi-byte literal gather of the two-phase expansion: every
    lane reads its own ``width``-byte little-endian value independently.
    ``byte_offs`` may be a scalar or any int32 array (shape is preserved).
    """
    v = jnp.take(data, byte_offs, mode="clip").astype(jnp.uint32)
    for i in range(1, width):
        v = v | (jnp.take(data, byte_offs + i, mode="clip").astype(jnp.uint32)
                 << jnp.uint32(8 * i))
    return v


# --------------------------------------------------------------------------
# output_stream
# --------------------------------------------------------------------------


class OutStream(NamedTuple):
    buf: jnp.ndarray     # (capacity,) element buffer; capacity >= out_len + pad
    pos: jnp.ndarray     # () int32 element position


def outstream(capacity: int, dtype) -> OutStream:
    return OutStream(buf=jnp.zeros((capacity,), dtype), pos=jnp.int32(0))


def write_byte(s: OutStream, v) -> OutStream:
    """Table II write_byte: single-element write (one 'thread' active)."""
    return s._replace(buf=s.buf.at[s.pos].set(v.astype(s.buf.dtype)),
                      pos=s.pos + 1)


def write_run(s: OutStream, init, length, delta, max_run: int) -> OutStream:
    """Table II write_run: every lane computes init + delta*lane independently
    (the paper's all-thread run expansion), blended into the buffer."""
    dt = s.buf.dtype
    idx = jnp.arange(max_run, dtype=jnp.uint32)
    vals = (init.astype(jnp.uint32) + delta.astype(jnp.uint32) * idx).astype(dt)
    cur = lax.dynamic_slice(s.buf, (s.pos,), (max_run,))
    new = jnp.where(idx < length.astype(jnp.uint32), vals, cur)
    return s._replace(buf=lax.dynamic_update_slice(s.buf, new, (s.pos,)),
                      pos=s.pos + length.astype(jnp.int32))


def write_from(s: OutStream, src: jnp.ndarray, src_start, length,
               max_len: int) -> OutStream:
    """Copy ``length`` elements from side buffer ``src`` (literal runs)."""
    win = lax.dynamic_slice(src, (src_start,), (max_len,))
    idx = jnp.arange(max_len, dtype=jnp.int32)
    cur = lax.dynamic_slice(s.buf, (s.pos,), (max_len,))
    new = jnp.where(idx < length, win.astype(s.buf.dtype), cur)
    return s._replace(buf=lax.dynamic_update_slice(s.buf, new, (s.pos,)),
                      pos=s.pos + length.astype(jnp.int32))


def memcpy(s: OutStream, offset, length, max_len: int) -> OutStream:
    """Table II / Alg. 2 memcpy: copy ``length`` elements from ``offset``
    elements back in the output itself.  When length > offset (dictionary
    self-overlap) the source is the circular window [pos-offset, pos) —
    implemented with modulo-indexed gather, the vector analogue of the
    paper's funnel-shift loop."""
    src_start = s.pos - offset.astype(jnp.int32)
    win = lax.dynamic_slice(s.buf, (src_start,), (max_len,))
    idx = jnp.arange(max_len, dtype=jnp.int32)
    idxm = jnp.where(offset > 0, idx % offset.astype(jnp.int32), idx)
    gathered = jnp.take(win, idxm, mode="clip")
    cur = lax.dynamic_slice(s.buf, (s.pos,), (max_len,))
    new = jnp.where(idx < length, gathered, cur)
    return s._replace(buf=lax.dynamic_update_slice(s.buf, new, (s.pos,)),
                      pos=s.pos + length.astype(jnp.int32))
