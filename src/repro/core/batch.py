"""Multi-blob batched decompression scheduler.

CODAG's throughput story is about *provisioning*: the hardware scheduler
hides decode latency only when a launch carries many independent streams.
Decoding N small ``CompressedBlob``s one dispatch at a time reproduces the
few-streams pathology of the RAPIDS baseline (paper Fig. 1a) — each launch
is under-provisioned and the scheduler starves.

This module coalesces a heterogeneous list of blobs (mixed codecs, widths,
chunk geometries) into per-``(codec, width, chunk_elems, bits)`` groups,
concatenates each group's chunk tables into ONE flat stream table
(``format.concat_blobs``), and issues a single engine dispatch per group.
Every chunk of every blob becomes an independent stream in one launch;
results are scattered back to per-blob outputs by row ranges.

    from repro.core import batch
    outs = batch.decompress_blobs(blobs)          # len(outs) == len(blobs)

or, with an inspectable plan (dispatch accounting for benchmarks/tests):

    plan = batch.BatchPlan.build(blobs)
    assert plan.num_dispatches == <number of distinct group keys>
    outs = plan.execute(engine)                   # host ndarrays
    devs = plan.execute_device(engine)            # device arrays, zero d2h

The device path is the ISSUE-4 tentpole: each ``GroupPlan`` carries the
per-blob scatter (``format.reassemble_indices``) precomputed at build time,
``stage()`` uploads the fused tables (and any index tables) ONCE, and
``execute_device`` runs decode → scatter → (optional fused epilogue) with
zero host syncs — wrap it in ``transfers.no_host_transfers()`` to prove it.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import format as fmt
from repro.core.engine import CodagEngine, EngineConfig


@functools.lru_cache(maxsize=None)
def _decode_scatter_fn():
    """The jitted decode→scatter kernel for one fused group (lazy so this
    module stays importable without jax initialization).

    One jit computation per (engine config, group statics, per-blob layout
    meta): the fused decode dispatch, every blob's row-range scatter, and
    the optional epilogue all trace together — executing the compiled
    function with pre-staged inputs performs zero host transfers in either
    direction, which is what lets ``execute_device`` run under
    ``transfers.no_host_transfers()``.
    """
    import jax

    @functools.partial(jax.jit, static_argnames=(
        "cfg", "codec", "width", "chunk_elems", "bits", "epilogue", "meta"))
    def decode_scatter(dev, scatter, *, cfg, codec, width, chunk_elems,
                       bits, epilogue, meta):
        table = CodagEngine(cfg).decompress_chunks(
            dev, codec=codec, width=width, chunk_elems=chunk_elems,
            bits=bits, epilogue=epilogue)
        outs = []
        for (row0, nc, total, odt, oshape, transformed), idx in zip(
                meta, scatter):
            outs.append(fmt.reassemble_rows_device(
                table, row0=row0, num_chunks=nc, total_elems=total,
                orig_dtype=odt, orig_shape=oshape, indices=idx,
                transformed=transformed))
        return outs

    return decode_scatter


@dataclasses.dataclass(frozen=True)
class GroupPlan:
    """One fused dispatch: the merged chunk table for one group key."""

    key: tuple                    # (codec, width, chunk_elems, bits)
    blob_ids: Tuple[int, ...]     # positions in the input blob list
    row_offsets: Tuple[int, ...]  # first chunk row of each blob in `merged`
    merged: fmt.CompressedBlob
    # per-blob device scatter (aligned with blob_ids): the precomputed flat
    # gather from format.reassemble_indices, or None when the blob's rows
    # are contiguous and reshape+trim suffices (the standard layout).
    scatter: Tuple[Optional[np.ndarray], ...] = ()

    @property
    def num_chunks(self) -> int:
        return self.merged.num_chunks


@dataclasses.dataclass
class BatchPlan:
    """Grouping of an input blob list into per-key fused dispatches."""

    blobs: List[fmt.CompressedBlob]
    groups: List[GroupPlan]
    # staged device inputs, lazily filled by stage(): group index ->
    # (device pytree, static bits); plus staged per-blob scatter indices.
    _staged: Dict[int, tuple] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    _staged_scatter: Dict[int, Any] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    # single-slot epilogue-operand cache: (original operands dict, staged
    # jnp dict).  Keyed by object identity — the strong ref to the original
    # keeps its id from being reused, so repeat calls with the same operand
    # dict are transfer-free.
    _staged_ops: Optional[tuple] = dataclasses.field(
        default=None, repr=False, compare=False)

    @classmethod
    def build(cls, blobs: Sequence[fmt.CompressedBlob]) -> "BatchPlan":
        blobs = list(blobs)
        by_key: Dict[tuple, List[int]] = {}
        for i, b in enumerate(blobs):
            by_key.setdefault(fmt.group_key(b), []).append(i)
        groups = []
        for key, ids in by_key.items():   # insertion order = first occurrence
            offsets, row = [], 0
            for i in ids:
                offsets.append(row)
                row += blobs[i].num_chunks
            groups.append(GroupPlan(
                key=key, blob_ids=tuple(ids), row_offsets=tuple(offsets),
                merged=fmt.concat_blobs([blobs[i] for i in ids]),
                scatter=tuple(fmt.reassemble_indices(blobs[i]) for i in ids)))
        return cls(blobs=blobs, groups=groups)

    @property
    def num_dispatches(self) -> int:
        return len(self.groups)

    @property
    def num_chunks(self) -> int:
        return sum(g.num_chunks for g in self.groups)

    def stage(self) -> "BatchPlan":
        """Upload every group's fused table (and any scatter index tables)
        to the device, once.  After staging, ``execute_device`` performs no
        host→device transfers — the decode→consume path can run under
        ``transfers.no_host_transfers()``."""
        import jax.numpy as jnp

        from repro.kernels import ops
        for gi, g in enumerate(self.groups):
            if gi not in self._staged:
                self._staged[gi] = ops.table_inputs(g.merged)
            if gi not in self._staged_scatter:
                self._staged_scatter[gi] = tuple(
                    None if s is None else jnp.asarray(s) for s in g.scatter)
        return self

    def execute(self, engine: Optional[CodagEngine] = None) -> List[np.ndarray]:
        """Run one engine dispatch per group; scatter back to input order."""
        engine = engine or CodagEngine(EngineConfig())
        outs: List[Optional[np.ndarray]] = [None] * len(self.blobs)
        for g in self.groups:
            table = engine.decompress_table(g.merged)
            for bid, row0 in zip(g.blob_ids, g.row_offsets):
                blob = self.blobs[bid]
                # copy: reassemble() of a contiguous slice is a view into the
                # whole group table — returning it would pin that table for
                # as long as any single output lives.
                rows = table[row0:row0 + blob.num_chunks].copy()
                outs[bid] = fmt.reassemble(blob, rows)
        return outs  # type: ignore[return-value]

    def execute_device(self, engine: Optional[CodagEngine] = None, *,
                       epilogue=None,
                       epilogue_operands: Optional[Dict[str, Any]] = None,
                       ) -> List[Any]:
        """Device-resident execute: one dispatch per group, per-blob scatter
        and the optional fused ``epilogue`` all on device.  Returns jax
        arrays in input order; with the plan pre-``stage()``d there are zero
        host transfers in either direction.

        ``epilogue_operands``: arrays for the epilogue's ``scale_key`` /
        ``zero_key`` device-pytree entries.  Staged on first use and cached
        by dict identity, so repeat calls with the same operands dict (the
        steady-state consumer pattern) perform no host→device transfer."""
        engine = engine or CodagEngine(EngineConfig())
        self.stage()
        ops_extra = {}
        if epilogue_operands:
            import jax.numpy as jnp
            if (self._staged_ops is not None
                    and self._staged_ops[0] is epilogue_operands):
                ops_extra = self._staged_ops[1]
            else:
                ops_extra = {k: jnp.asarray(v)
                             for k, v in epilogue_operands.items()}
                self._staged_ops = (epilogue_operands, ops_extra)
        outs: List[Any] = [None] * len(self.blobs)
        decode_scatter = _decode_scatter_fn()
        for gi, g in enumerate(self.groups):
            dev, bits = self._staged[gi]
            if ops_extra:
                dev = {**dev, **ops_extra}
            codec, width, chunk_elems, _ = g.key
            meta = tuple(
                (row0, self.blobs[bid].num_chunks,
                 self.blobs[bid].total_elems, self.blobs[bid].orig_dtype,
                 tuple(self.blobs[bid].orig_shape), epilogue is not None)
                for bid, row0 in zip(g.blob_ids, g.row_offsets))
            group_outs = decode_scatter(
                dev, list(self._staged_scatter[gi]), cfg=engine.config,
                codec=codec, width=width, chunk_elems=chunk_elems,
                bits=bits, epilogue=epilogue, meta=meta)
            for bid, out in zip(g.blob_ids, group_outs):
                outs[bid] = out
        return outs


def decompress_blobs(blobs: Sequence[fmt.CompressedBlob],
                     engine: Optional[CodagEngine] = None,
                     device_out: bool = False,
                     epilogue=None) -> List:
    """Batched ``engine.decompress`` over many blobs: one dispatch per
    (codec, width, chunk_elems, bits) group, outputs in input order.
    ``device_out=True`` keeps every output on device (jax arrays, no host
    sync); ``epilogue`` fuses a consumer transform into each dispatch
    (device path only)."""
    if not blobs:
        return []
    plan = BatchPlan.build(blobs)
    if device_out:
        return plan.execute_device(engine, epilogue=epilogue)
    if epilogue is not None:
        raise ValueError("epilogue requires device_out=True: a fused "
                         "epilogue's output has no host reassembly path")
    return plan.execute(engine)
