"""Multi-blob batched decompression scheduler.

CODAG's throughput story is about *provisioning*: the hardware scheduler
hides decode latency only when a launch carries many independent streams.
Decoding N small ``CompressedBlob``s one dispatch at a time reproduces the
few-streams pathology of the RAPIDS baseline (paper Fig. 1a) — each launch
is under-provisioned and the scheduler starves.

This module coalesces a heterogeneous list of blobs (mixed codecs, widths,
chunk geometries) into per-``(codec, width, chunk_elems, bits)`` groups,
concatenates each group's chunk tables into ONE flat stream table
(``format.concat_blobs``), and issues a single engine dispatch per group.
Every chunk of every blob becomes an independent stream in one launch;
results are scattered back to per-blob ndarrays by row ranges.

    from repro.core import batch
    outs = batch.decompress_blobs(blobs)          # len(outs) == len(blobs)

or, with an inspectable plan (dispatch accounting for benchmarks/tests):

    plan = batch.BatchPlan.build(blobs)
    assert plan.num_dispatches == <number of distinct group keys>
    outs = plan.execute(engine)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import format as fmt
from repro.core.engine import CodagEngine, EngineConfig


@dataclasses.dataclass(frozen=True)
class GroupPlan:
    """One fused dispatch: the merged chunk table for one group key."""

    key: tuple                    # (codec, width, chunk_elems, bits)
    blob_ids: Tuple[int, ...]     # positions in the input blob list
    row_offsets: Tuple[int, ...]  # first chunk row of each blob in `merged`
    merged: fmt.CompressedBlob

    @property
    def num_chunks(self) -> int:
        return self.merged.num_chunks


@dataclasses.dataclass
class BatchPlan:
    """Grouping of an input blob list into per-key fused dispatches."""

    blobs: List[fmt.CompressedBlob]
    groups: List[GroupPlan]

    @classmethod
    def build(cls, blobs: Sequence[fmt.CompressedBlob]) -> "BatchPlan":
        blobs = list(blobs)
        by_key: Dict[tuple, List[int]] = {}
        for i, b in enumerate(blobs):
            by_key.setdefault(fmt.group_key(b), []).append(i)
        groups = []
        for key, ids in by_key.items():   # insertion order = first occurrence
            offsets, row = [], 0
            for i in ids:
                offsets.append(row)
                row += blobs[i].num_chunks
            groups.append(GroupPlan(
                key=key, blob_ids=tuple(ids), row_offsets=tuple(offsets),
                merged=fmt.concat_blobs([blobs[i] for i in ids])))
        return cls(blobs=blobs, groups=groups)

    @property
    def num_dispatches(self) -> int:
        return len(self.groups)

    @property
    def num_chunks(self) -> int:
        return sum(g.num_chunks for g in self.groups)

    def execute(self, engine: Optional[CodagEngine] = None) -> List[np.ndarray]:
        """Run one engine dispatch per group; scatter back to input order."""
        engine = engine or CodagEngine(EngineConfig())
        outs: List[Optional[np.ndarray]] = [None] * len(self.blobs)
        for g in self.groups:
            table = engine.decompress_table(g.merged)
            for bid, row0 in zip(g.blob_ids, g.row_offsets):
                blob = self.blobs[bid]
                # copy: reassemble() of a contiguous slice is a view into the
                # whole group table — returning it would pin that table for
                # as long as any single output lives.
                rows = table[row0:row0 + blob.num_chunks].copy()
                outs[bid] = fmt.reassemble(blob, rows)
        return outs  # type: ignore[return-value]


def decompress_blobs(blobs: Sequence[fmt.CompressedBlob],
                     engine: Optional[CodagEngine] = None) -> List[np.ndarray]:
    """Batched ``engine.decompress`` over many blobs: one dispatch per
    (codec, width, chunk_elems, bits) group, outputs in input order."""
    if not blobs:
        return []
    return BatchPlan.build(blobs).execute(engine)
