"""Multi-blob batched decompression scheduler — compat surface.

The scheduler's machinery (grouping, staging, scatter, the jitted
decode→scatter executors) lives in :mod:`repro.core.plan` as the unified
``DecodePlan`` IR; this module keeps the original public names working:

    from repro.core import batch
    outs = batch.decompress_blobs(blobs)          # len(outs) == len(blobs)

    plan = batch.BatchPlan.build(blobs)           # == plan.DecodePlan.build
    assert plan.num_dispatches == <number of distinct group keys>
    outs = plan.execute(engine)                   # host ndarrays
    devs = plan.execute_device(engine)            # device arrays, zero d2h
    shrd = plan.execute_sharded(mesh)             # mesh-sharded decode
"""
from __future__ import annotations

from repro.core import plan as _plan

DecodePlan = _plan.DecodePlan
PlanGroup = _plan.PlanGroup
decompress_blobs = _plan.decompress_blobs

# historical names (PR 1/PR 4 era)
BatchPlan = _plan.DecodePlan
GroupPlan = _plan.PlanGroup
