"""Host-side (numpy) encoders for the CODAG-JAX codecs.

The decoders (the paper's subject) live in ``repro.kernels``; encoding is a
host/offline concern in the paper too (datasets are compressed with the ORC
tools / zlib).  Group structures follow DESIGN.md §2:

RLE v1  (byte-aligned, fixed-width values; ORC RLE v1 control structure)
  control c in [0,127]   -> run of length c+3 (3..130), one value follows
  control c in [128,255] -> 256-c literals (1..128), values follow

RLE v2  (adds delta + long-run modes; ORC RLE v2 in spirit)
  header h; mode = h >> 6, f = h & 63
  mode 0 -> run,     len = f+3  (3..66),  value follows
  mode 1 -> delta,   len = f+3  (3..66),  base value + delta value follow
  mode 2 -> literal, len = f+1  (1..64),  values follow
  mode 3 -> long run, len = (f<<8 | next_byte)+3 (3..16386), value follows

tdeflate (Deflate semantics, chunk-local window, LSB-first bitstream,
  canonical length-limited (<=12 bit) Huffman over the deflate litlen(286)
  and distance(30) alphabets; codes stored bit-reversed so the decoder can
  index a flat LUT with a 12-bit peek)

bitpack  (b bits/elem, LSB-first into uint32 words — used for compressed
  gradients / optimizer state / KV cache)
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from . import format as fmt

# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------


def _values_bytes(vals: np.ndarray, width: int) -> bytes:
    return np.ascontiguousarray(vals).astype(
        {1: np.uint8, 2: np.uint16, 4: np.uint32}[width]
    ).tobytes()


def _find_runs(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Return (start_indices, run_lengths) of maximal equal-value runs."""
    n = x.shape[0]
    if n == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    change = np.empty(n, np.bool_)
    change[0] = True
    np.not_equal(x[1:], x[:-1], out=change[1:])
    starts = np.flatnonzero(change)
    lens = np.diff(np.append(starts, n))
    return starts, lens


# --------------------------------------------------------------------------
# RLE v1
# --------------------------------------------------------------------------

RLE1_MIN_RUN = 3
RLE1_MAX_RUN = 130
RLE1_MAX_LIT = 128


def encode_rle_v1_chunk(x: np.ndarray, width: int) -> bytes:
    starts, lens = _find_runs(x)
    out = bytearray()
    lit_start = None  # start elem index of pending literal group

    def flush_literals(end: int) -> None:
        nonlocal lit_start
        if lit_start is None:
            return
        i = lit_start
        while i < end:
            n = min(RLE1_MAX_LIT, end - i)
            out.append(256 - n)
            out.extend(_values_bytes(x[i : i + n], width))
            i += n
        lit_start = None

    for s, l in zip(starts.tolist(), lens.tolist()):
        if l >= RLE1_MIN_RUN:
            flush_literals(s)
            rem, pos = l, s
            while rem >= RLE1_MIN_RUN:
                n = min(RLE1_MAX_RUN, rem)
                if rem - n in (1, 2):  # avoid leaving an un-encodable tail
                    n = rem - RLE1_MIN_RUN
                    if n < RLE1_MIN_RUN:
                        break
                out.append(n - RLE1_MIN_RUN)
                out.extend(_values_bytes(x[pos : pos + 1], width))
                pos += n
                rem -= n
            if rem:  # leftover 1..2 become literals
                if lit_start is None:
                    lit_start = pos
        else:
            if lit_start is None:
                lit_start = s
    flush_literals(x.shape[0])
    return bytes(out)


# --------------------------------------------------------------------------
# RLE v2 (run / delta / literal / long-run)
# --------------------------------------------------------------------------

RLE2_MIN_RUN = 3
RLE2_MAX_SHORT = 66
RLE2_MAX_LONG = 16386
RLE2_MAX_LIT = 64
RLE2_MIN_DELTA = 4


def encode_rle_v2_chunk(x: np.ndarray, width: int) -> bytes:
    n = x.shape[0]
    out = bytearray()
    if n == 0:
        return b""
    # Segment by constant *difference* (wraparound arithmetic): an equal-value
    # run is a delta segment with d == 0.
    d = (x[1:] - x[:-1]) if n > 1 else np.zeros(0, x.dtype)
    dstarts, dlens = _find_runs(d) if n > 1 else (np.zeros(0, np.int64),) * 2

    lit_start: int | None = None

    def flush_literals(end: int) -> None:
        nonlocal lit_start
        if lit_start is None:
            return
        i = lit_start
        while i < end:
            m = min(RLE2_MAX_LIT, end - i)
            out.append((2 << 6) | (m - 1))
            out.extend(_values_bytes(x[i : i + m], width))
            i += m
        lit_start = None

    def emit_run(pos: int, length: int) -> None:
        val = x[pos : pos + 1]
        rem = length
        while rem >= RLE2_MIN_RUN:
            m = min(RLE2_MAX_LONG, rem)
            if rem - m in (1, 2):
                m = rem - RLE2_MIN_RUN
            if m <= RLE2_MAX_SHORT:
                out.append((0 << 6) | (m - 3))
            else:
                out.append((3 << 6) | ((m - 3) >> 8))
                out.append((m - 3) & 0xFF)
            out.extend(_values_bytes(val, width))
            pos += m
            rem -= m
        assert rem == 0

    def emit_delta(pos: int, length: int, delta) -> None:
        rem, p = length, pos
        while rem >= RLE2_MIN_RUN:
            m = min(RLE2_MAX_SHORT, rem)
            if rem - m in (1, 2):
                m = rem - RLE2_MIN_RUN
            out.append((1 << 6) | (m - 3))
            out.extend(_values_bytes(x[p : p + 1], width))
            out.extend(_values_bytes(np.asarray([delta], x.dtype), width))
            p += m
            rem -= m
        assert rem == 0

    dends = dstarts + dlens  # exclusive end, in diff-index space
    nseg = dstarts.shape[0]
    i = 0   # element cursor
    seg = 0
    while i < n:
        if i >= n - 1:
            # trailing single element -> literal
            if lit_start is None:
                lit_start = i
            break
        while seg < nseg and int(dends[seg]) <= i:
            seg += 1
        # invariant: dstarts[seg] <= i < dends[seg]; the constant-diff segment
        # covers elements [i, dends[seg]] inclusive.
        delta = d[i]
        elems = int(dends[seg]) - i + 1
        if delta == 0 and elems >= RLE2_MIN_RUN:
            flush_literals(i)
            emit_run(i, elems)
            i += elems
        elif delta != 0 and elems >= RLE2_MIN_DELTA:
            flush_literals(i)
            emit_delta(i, elems, delta)
            i += elems
        else:
            if lit_start is None:
                lit_start = i
            i = int(dends[seg])  # last element of segment joins the next one
    flush_literals(n)
    return bytes(out)


# --------------------------------------------------------------------------
# tdeflate: LZ77 + canonical length-limited Huffman
# --------------------------------------------------------------------------

MAX_CODE_BITS = 12
LUT_SIZE = 1 << MAX_CODE_BITS
EOB = 256
NUM_LITLEN = 286
NUM_DIST = 30
MIN_MATCH = 3
MAX_MATCH = 258

# deflate length code table: code 257+i -> (extra_bits, base_length)
LEN_EXTRA = np.array([0,0,0,0,0,0,0,0,1,1,1,1,2,2,2,2,3,3,3,3,4,4,4,4,5,5,5,5,0], np.int32)
LEN_BASE = np.array([3,4,5,6,7,8,9,10,11,13,15,17,19,23,27,31,35,43,51,59,67,83,99,115,131,163,195,227,258], np.int32)
DIST_EXTRA = np.array([0,0,0,0,1,1,2,2,3,3,4,4,5,5,6,6,7,7,8,8,9,9,10,10,11,11,12,12,13,13], np.int32)
DIST_BASE = np.array([1,2,3,4,5,7,9,13,17,25,33,49,65,97,129,193,257,385,513,769,1025,1537,2049,3073,4097,6145,8193,12289,16385,24577], np.int32)


def _length_code(l: int) -> int:
    return int(np.searchsorted(LEN_BASE, l, side="right")) - 1


def _dist_code(dist: int) -> int:
    return int(np.searchsorted(DIST_BASE, dist, side="right")) - 1


def limited_huffman_lengths(freqs: np.ndarray, max_bits: int = MAX_CODE_BITS) -> np.ndarray:
    """Optimal-ish Huffman code lengths limited to ``max_bits`` (zlib-style)."""
    n = freqs.shape[0]
    active = np.flatnonzero(freqs > 0)
    lengths = np.zeros(n, np.int32)
    if active.size == 0:
        return lengths
    if active.size == 1:
        lengths[active[0]] = 1
        return lengths
    # Build Huffman tree with a simple two-queue merge.
    import heapq

    heap = [(int(freqs[i]), int(i), 0) for i in active]  # (freq, id, depth-tag)
    heapq.heapify(heap)
    parent: Dict[int, int] = {}
    next_id = n
    while len(heap) > 1:
        f1, i1, _ = heapq.heappop(heap)
        f2, i2, _ = heapq.heappop(heap)
        parent[i1] = next_id
        parent[i2] = next_id
        heapq.heappush(heap, (f1 + f2, next_id, 0))
        next_id += 1
    for i in active:
        d, j = 0, int(i)
        while j in parent:
            j = parent[j]
            d += 1
        lengths[i] = d
    # Length-limit with Kraft fix-up.
    if lengths.max() > max_bits:
        lengths = np.minimum(lengths, max_bits)
        # Kraft sum in units of 2^-max_bits
        kraft = int(np.sum((1 << (max_bits - lengths[lengths > 0])).astype(np.int64)))
        limit = 1 << max_bits
        # overflow: demote shortest overfull codes (increase length)
        order = np.argsort(lengths + (lengths == 0) * 1000, kind="stable")
        while kraft > limit:
            # find a symbol with length < max_bits and increment it
            for i in order[::-1]:
                li = lengths[i]
                if 0 < li < max_bits:
                    lengths[i] = li + 1
                    kraft -= 1 << (max_bits - li - 1)
                    break
            else:  # pragma: no cover
                raise RuntimeError("kraft fixup failed")
        # underflow: promote (shorten) to use slack — optional, skip (valid code)
    return lengths


def canonical_codes(lengths: np.ndarray) -> np.ndarray:
    """Canonical codes (deflate convention: sorted by (length, symbol))."""
    max_len = int(lengths.max()) if lengths.size else 0
    bl_count = np.bincount(lengths, minlength=max_len + 1)
    bl_count[0] = 0
    code = 0
    next_code = np.zeros(max_len + 1, np.int64)
    for bits in range(1, max_len + 1):
        code = (code + int(bl_count[bits - 1])) << 1
        next_code[bits] = code
    codes = np.zeros_like(lengths, dtype=np.int64)
    for sym in range(lengths.shape[0]):
        l = int(lengths[sym])
        if l:
            codes[sym] = next_code[l]
            next_code[l] += 1
    return codes


def _bit_reverse(v: int, bits: int) -> int:
    r = 0
    for _ in range(bits):
        r = (r << 1) | (v & 1)
        v >>= 1
    return r


def build_decode_lut(lengths: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Flat (sym, nbits) LUT indexed by a MAX_CODE_BITS LSB-first peek."""
    codes = canonical_codes(lengths)
    lut_sym = np.zeros(LUT_SIZE, np.int16)
    lut_bits = np.zeros(LUT_SIZE, np.int8)
    for sym in range(lengths.shape[0]):
        l = int(lengths[sym])
        if not l:
            continue
        rc = _bit_reverse(int(codes[sym]), l)
        step = 1 << l
        for v in range(rc, LUT_SIZE, step):
            lut_sym[v] = sym
            lut_bits[v] = l
    return lut_sym, lut_bits


class _BitWriter:
    __slots__ = ("buf", "acc", "nbits")

    def __init__(self) -> None:
        self.buf = bytearray()
        self.acc = 0
        self.nbits = 0

    def write(self, value: int, bits: int) -> None:
        self.acc |= (value & ((1 << bits) - 1)) << self.nbits
        self.nbits += bits
        while self.nbits >= 8:
            self.buf.append(self.acc & 0xFF)
            self.acc >>= 8
            self.nbits -= 8

    def finish(self) -> bytes:
        if self.nbits:
            self.buf.append(self.acc & 0xFF)
            self.acc, self.nbits = 0, 0
        return bytes(self.buf)


def _lz77_tokens(data: bytes) -> List[Tuple]:
    """Greedy LZ77 with a hash-of-4 chain (single probe + extension)."""
    n = len(data)
    tokens: List[Tuple] = []
    head: Dict[int, int] = {}
    i = 0
    mv = memoryview(data)
    while i < n:
        if i + MIN_MATCH + 1 <= n:
            key = data[i] | (data[i + 1] << 8) | (data[i + 2] << 16) | (data[i + 3] << 24) if i + 4 <= n else data[i] | (data[i + 1] << 8) | (data[i + 2] << 16)
            cand = head.get(key, -1)
            head[key] = i
            if cand >= 0 and i - cand <= DIST_BASE[-1] + (1 << DIST_EXTRA[-1]) - 1:
                # extend match
                m = 0
                lim = min(MAX_MATCH, n - i)
                while m < lim and data[cand + m] == data[i + m]:
                    m += 1
                if m >= MIN_MATCH:
                    tokens.append(("m", m, i - cand))
                    # insert a few hash entries inside the match for better chains
                    end = min(i + m, n - 4)
                    for j in range(i + 1, min(i + 4, end)):
                        k2 = data[j] | (data[j + 1] << 8) | (data[j + 2] << 16) | (data[j + 3] << 24)
                        head[k2] = j
                    i += m
                    continue
        tokens.append(("l", data[i]))
        i += 1
    del mv
    return tokens


def encode_tdeflate_chunk(x: np.ndarray) -> Tuple[bytes, np.ndarray, np.ndarray]:
    """Encode a uint8 chunk. Returns (payload, litlen_lengths, dist_lengths)."""
    data = x.astype(np.uint8).tobytes()
    tokens = _lz77_tokens(data)
    # symbol frequencies
    lfreq = np.zeros(NUM_LITLEN, np.int64)
    dfreq = np.zeros(NUM_DIST, np.int64)
    for t in tokens:
        if t[0] == "l":
            lfreq[t[1]] += 1
        else:
            lfreq[257 + _length_code(t[1])] += 1
            dfreq[_dist_code(t[2])] += 1
    lfreq[EOB] += 1
    llen = limited_huffman_lengths(lfreq)
    dlen = limited_huffman_lengths(dfreq)
    lcodes = canonical_codes(llen)
    dcodes = canonical_codes(dlen)
    # pre-reverse codes for LSB-first emission
    lrev = [(_bit_reverse(int(lcodes[s]), int(llen[s])), int(llen[s])) for s in range(NUM_LITLEN)]
    drev = [(_bit_reverse(int(dcodes[s]), int(dlen[s])), int(dlen[s])) for s in range(NUM_DIST)]
    w = _BitWriter()
    for t in tokens:
        if t[0] == "l":
            c, nb = lrev[t[1]]
            w.write(c, nb)
        else:
            _, length, dist = t
            lc = _length_code(length)
            c, nb = lrev[257 + lc]
            w.write(c, nb)
            eb = int(LEN_EXTRA[lc])
            if eb:
                w.write(length - int(LEN_BASE[lc]), eb)
            dc = _dist_code(dist)
            c, nb = drev[dc]
            w.write(c, nb)
            eb = int(DIST_EXTRA[dc])
            if eb:
                w.write(dist - int(DIST_BASE[dc]), eb)
    c, nb = lrev[EOB]
    w.write(c, nb)
    return w.finish(), llen.astype(np.uint8), dlen.astype(np.uint8)


# --------------------------------------------------------------------------
# bitpack
# --------------------------------------------------------------------------


def pack_bits(x: np.ndarray, bits: int) -> np.ndarray:
    """Pack non-negative ints (< 2^bits) LSB-first into uint32 words."""
    assert 1 <= bits <= 32
    n = x.shape[0]
    x = x.astype(np.uint64) & ((1 << bits) - 1)
    total_bits = n * bits
    nwords = (total_bits + 31) // 32
    out = np.zeros(nwords + 1, np.uint64)  # +1 slack for spill
    idx = np.arange(n, dtype=np.uint64) * bits
    word = (idx >> 5).astype(np.int64)
    off = (idx & 31).astype(np.uint64)
    lo = (x << off) & np.uint64(0xFFFFFFFF)
    shift = (np.uint64(32) - off) % np.uint64(64)
    hi = np.where(off > 0, x >> shift, np.uint64(0))
    # Bit-fields of distinct elements are disjoint, so scatter-add == OR.
    np.add.at(out, word, lo)
    np.add.at(out, word + 1, hi)
    return (out[:nwords] & np.uint64(0xFFFFFFFF)).astype(np.uint32)


def encode_bitpack_chunk(x: np.ndarray, bits: int) -> bytes:
    return pack_bits(x.astype(np.uint64), bits).tobytes()


# --------------------------------------------------------------------------
# per-codec blob builders (each registered as its plugin's ``encode`` hook)
# --------------------------------------------------------------------------


def compress_rle_v1(arr: np.ndarray,
                    chunk_bytes: int = fmt.DEFAULT_CHUNK_BYTES,
                    bits: int | None = None) -> fmt.CompressedBlob:
    chunks, chunk_elems, width, _ = fmt.chunk_array(arr, chunk_bytes)
    encoded = [encode_rle_v1_chunk(c, width) for c in chunks]
    return fmt.build_blob(fmt.RLE_V1, arr, encoded, chunk_elems, width)


def compress_rle_v2(arr: np.ndarray,
                    chunk_bytes: int = fmt.DEFAULT_CHUNK_BYTES,
                    bits: int | None = None) -> fmt.CompressedBlob:
    chunks, chunk_elems, width, _ = fmt.chunk_array(arr, chunk_bytes)
    encoded = [encode_rle_v2_chunk(c, width) for c in chunks]
    return fmt.build_blob(fmt.RLE_V2, arr, encoded, chunk_elems, width)


def compress_tdeflate(arr: np.ndarray,
                      chunk_bytes: int = fmt.DEFAULT_CHUNK_BYTES,
                      bits: int | None = None) -> fmt.CompressedBlob:
    chunks, chunk_elems, width, _ = fmt.chunk_array(arr, chunk_bytes)
    # tdeflate is a byte codec: re-chunk at byte granularity
    chunks = [np.ascontiguousarray(c).view(np.uint8) for c in chunks]
    luts_ls, luts_lb, luts_ds, luts_db = [], [], [], []
    hdr_l, hdr_d = [], []
    payloads = []
    for c in chunks:
        payload, llen, dlen = encode_tdeflate_chunk(c)
        payloads.append(payload)
        ls, lb = build_decode_lut(llen.astype(np.int32))
        ds, db = build_decode_lut(dlen.astype(np.int32))
        luts_ls.append(ls); luts_lb.append(lb)
        luts_ds.append(ds); luts_db.append(db)
        hdr_l.append(llen); hdr_d.append(dlen)
    extras = {
        "lut_lsym": np.stack(luts_ls), "lut_lbits": np.stack(luts_lb),
        "lut_dsym": np.stack(luts_ds), "lut_dbits": np.stack(luts_db),
        "hdr_llen": np.stack(hdr_l), "hdr_dlen": np.stack(hdr_d),
    }
    total_bytes = sum(int(c.shape[0]) for c in chunks)
    return fmt.build_blob(fmt.TDEFLATE, arr, payloads, chunk_elems * width,
                          1, extras, total_elems=total_bytes)


def compress_bitpack(arr: np.ndarray,
                     chunk_bytes: int = fmt.DEFAULT_CHUNK_BYTES,
                     bits: int | None = None) -> fmt.CompressedBlob:
    chunks, chunk_elems, width, _ = fmt.chunk_array(arr, chunk_bytes)
    if bits is None:
        maxv = max((int(c.max()) for c in chunks if c.size), default=0)
        bits = max(1, maxv.bit_length())
    encoded = [encode_bitpack_chunk(c, bits) for c in chunks]
    extras = {"bitpack_bits": np.full((1,), bits, np.int32)}
    return fmt.build_blob(fmt.BITPACK, arr, encoded, chunk_elems, width, extras)


# --------------------------------------------------------------------------
# top-level compress(): pure registry dispatch, no per-codec branches
# --------------------------------------------------------------------------


def compress(arr: np.ndarray, codec: str,
             chunk_bytes: int | None = None,
             bits: int | None = None) -> fmt.CompressedBlob:
    """Encode ``arr`` through the codec registry.

    ``chunk_bytes=None`` (the default) resolves the tuned chunk size for
    this (codec, element width) on the current device from the
    tuned-defaults table (``core.tuning``), falling back to
    ``format.DEFAULT_CHUNK_BYTES``; an explicit value always wins.
    """
    from repro.core import registry, tuning
    if chunk_bytes is None:
        chunk_bytes = tuning.chunk_bytes_for(
            codec, tuning.encode_width(codec, arr.dtype))
        if chunk_bytes is None:
            chunk_bytes = fmt.DEFAULT_CHUNK_BYTES
    return registry.get(codec).encode(arr, chunk_bytes, bits=bits)
