"""Async decompression service with adaptive micro-batching.

The batch scheduler (``core.batch``) fuses blobs that arrive *together* in
one call.  A serving workload does not arrive together: requests trickle in
per-tensor from many producers, and decoding each on arrival reproduces the
few-streams provisioning pathology of paper Fig. 1a launch by launch — every
dispatch carries one blob's chunks instead of a saturated stream table.

``DecompressionService`` closes that gap.  Producers submit blobs from any
thread and get a ``concurrent.futures.Future`` back; a single worker thread
coalesces everything that arrives inside an adaptive micro-batching window

  * flush when the window holds ``max_batch_blobs`` blobs, or
  * flush when ``max_delay_ms`` has elapsed since the window opened, or
  * flush early when the queue goes idle for ``idle_ms`` (adaptive part:
    a burst is fused whole, a lone straggler is not held hostage),

builds ONE fused chunk table per ``(codec, width, chunk_elems, bits)`` group
per window (``format.concat_blobs``), and resolves each request's future
from the scattered rows.  Concurrent same-group requests therefore share a
single engine dispatch — dispatch amplification < 1.0 vs. per-blob decode.

In front of the dispatch path sits a decoded-blob LRU cache keyed by a
content digest of the compressed payload (``blob_digest``) and bounded by a
byte budget; repeated blobs (hot shards, shared embedding planes) resolve
without touching the engine.  Identical blobs inside one window are deduped
into a single decode as well.

    svc = DecompressionService(max_batch_blobs=64, max_delay_ms=2.0)
    fut = svc.submit(blob)           # any thread
    out = fut.result()               # decoded ndarray, bit-exact
    svc.stats()                      # blobs/window, dispatches/window,
                                     # cache hit rate, p50/p99 latency
    svc.close()                      # graceful: drains, then joins

``api.decompress_many`` routes through a process-wide default service
(``default_service()``); ``checkpoint.restore(..., service=)`` and
``data.pipeline.CompressedLoader(service=)`` opt consumers in explicitly.
"""
from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import format as fmt
from repro.core import plan as plan_mod
from repro.core import transfers
from repro.core.engine import CodagEngine, EngineConfig

_CLOSE = object()          # queue sentinel; nothing is enqueued after it

# Moved to core/format.py (the plan executor's staging caches need them
# too); re-exported here for compatibility — same objects, one definition.
blob_digest = fmt.blob_digest
pad_table_to_bucket = fmt.pad_table_to_bucket


class _LRUCache:
    """Byte-budgeted LRU of decoded ndarrays. Not thread-safe on its own —
    the service touches it from the worker thread under the service lock."""

    def __init__(self, max_bytes: int):
        self.max_bytes = int(max_bytes)
        self._entries: "collections.OrderedDict[str, np.ndarray]" = \
            collections.OrderedDict()
        self.bytes = 0

    def get(self, key: str) -> Optional[np.ndarray]:
        arr = self._entries.get(key)
        if arr is not None:
            self._entries.move_to_end(key)
        return arr

    def put(self, key: str, arr: np.ndarray) -> None:
        if arr.nbytes > self.max_bytes:
            return
        if key in self._entries:
            # content-keyed: the stored value is identical, but a re-put is
            # a use — refresh recency so hot digests don't age out as cold.
            self._entries.move_to_end(key)
            return
        stored = arr.copy()          # private copy: callers may mutate theirs
        stored.flags.writeable = False
        self._entries[key] = stored
        self.bytes += stored.nbytes
        while self.bytes > self.max_bytes and self._entries:
            _, evicted = self._entries.popitem(last=False)
            self.bytes -= evicted.nbytes

    def __len__(self) -> int:
        return len(self._entries)


@dataclasses.dataclass
class _Request:
    blob: fmt.CompressedBlob
    future: Future
    t_submit: float
    # content digest, precomputed on the producer thread when the cache is
    # on (hashing parallelizes across producers; the worker stays on the
    # dispatch path).  None when the cache is off — the worker then dedupes
    # by blob object identity instead of content.
    digest: Optional[str] = None
    # resolve with a device-resident jax array instead of a host ndarray
    # (the decoded-blob cache keeps host bytes either way and hands device
    # requesters a view of them on a hit).
    device: bool = False


@dataclasses.dataclass(frozen=True)
class ServiceStats:
    """Cumulative snapshot; rates/percentiles derived at snapshot time."""

    windows: int
    blobs: int
    dispatches: int
    cache_hits: int
    cache_misses: int
    errors: int
    cache_bytes: int
    blobs_per_window: float
    dispatches_per_window: float
    cache_hit_rate: float
    latency_p50_ms: float
    latency_p99_ms: float
    # per-device dispatch accounting (multi-device services only): device
    # string -> fused dispatches scheduled onto it by the round-robin
    # group→device assignment.  Empty for single-device services.
    device_dispatches: Dict[str, int] = dataclasses.field(
        default_factory=dict)

    @property
    def dispatch_amplification(self) -> float:
        """Engine dispatches per submitted blob; < 1.0 means coalescing wins
        over the one-dispatch-per-blob baseline."""
        return self.dispatches / max(1, self.blobs)


class DecompressionService:
    """Micro-batching decode front-end; see module docstring.

    Parameters
    ----------
    engine:           the CodagEngine every fused dispatch runs on.
    max_batch_blobs:  flush the window once it holds this many blobs.  An
                      atomic ``submit_many`` larger than this stays whole.
    max_delay_ms:     hard latency bound — flush this long after the first
                      blob of the window arrived even if requests keep
                      trickling in.
    idle_ms:          flush early once the queue has been idle this long
                      (<= max_delay_ms).  Small values favor latency, values
                      equal to ``max_delay_ms`` favor coalescing.
    cache_bytes:      decoded-blob LRU budget; 0 disables the cache.
    bucket_shapes:    pad fused tables to power-of-two buckets
                      (``pad_table_to_bucket``) so steady-state windows hit
                      the jit cache instead of recompiling.  Costs up to 2x
                      zero rows per dispatch; disable for exact per-call
                      dispatch geometry (the default service disables both
                      this and the cache, for ``decompress_many``'s
                      one-shot batches).
    bucket_cols_floor: explicit minimum pow2 column bucket for fused
                      window tables; None consults the tuned-defaults
                      table (``core.tuning``), falling back to 128.
    compile_cache:    wire up jax's persistent compilation cache via
                      ``tuning.enable_compile_cache`` — True for the
                      default directory, or a path.  A restarted replica
                      then loads its decode kernels from disk instead of
                      recompiling them.
    devices:          optional list of ``jax.Device``s — each window's
                      fused group dispatches are assigned round-robin
                      across them (group i → device (rr+i) mod N), with
                      per-device dispatch counts in ``ServiceStats``.  A
                      mesh of decompressors behind one submit queue; None
                      keeps the single default device.
    store:            optional ``core.store.TieredBlobStore`` — the lower
                      tiers behind this service's decoded-blob LRU (which
                      becomes the store's TIER 0).  ``submit_key(key)``
                      then resolves a request for a blob that is NOT in
                      host memory by demand-paging it through the store's
                      host-cache/backend tiers (on the store's prefetch
                      pool — the service worker never blocks on I/O) and
                      decoding on arrival; repeats hit the decoded cache.
    latency_window:   how many recent request latencies feed p50/p99.
    """

    def __init__(self, engine: Optional[CodagEngine] = None, *,
                 max_batch_blobs: int = 64, max_delay_ms: float = 2.0,
                 idle_ms: Optional[float] = None,
                 cache_bytes: int = 32 << 20,
                 bucket_shapes: bool = True,
                 bucket_cols_floor: Optional[int] = None,
                 compile_cache=None,
                 devices: Optional[Sequence] = None,
                 store=None,
                 latency_window: int = 4096):
        if max_batch_blobs < 1:
            raise ValueError("max_batch_blobs must be >= 1")
        if compile_cache:
            # persistent jit cache: a replica restart reloads its decode
            # kernels from disk instead of re-paying XLA compilation.
            # True = the default cache dir; a path pins the location.
            from repro.core import tuning
            tuning.enable_compile_cache(
                None if compile_cache is True else compile_cache)
        self.engine = engine or CodagEngine(EngineConfig())
        self.max_batch_blobs = int(max_batch_blobs)
        self.max_delay_ms = float(max_delay_ms)
        self.idle_ms = min(float(idle_ms if idle_ms is not None else 0.5),
                           self.max_delay_ms) if max_delay_ms > 0 else 0.0
        self.bucket_shapes = bool(bucket_shapes)
        # explicit pow2-bucketing column floor; None = consult the tuned
        # defaults inside pad_table_to_bucket (historical 128 fallback)
        self.bucket_cols_floor = bucket_cols_floor
        self._q: "queue.Queue" = queue.Queue()
        self._lock = threading.Lock()
        self._closed = False
        self._cache = _LRUCache(cache_bytes) if cache_bytes > 0 else None
        self._latencies: "collections.deque[float]" = collections.deque(
            maxlen=latency_window)
        self.store = store
        if store is not None:
            store.attach_tier0(self)   # store.stats() surfaces tier-0 LRU
        self._devices = list(devices) if devices else []
        self._rr = 0                       # round-robin device cursor
        self._device_dispatches: Dict[str, int] = {}
        self._windows = 0
        self._blobs = 0
        self._dispatches = 0
        self._cache_hits = 0
        self._cache_misses = 0
        self._errors = 0
        self._worker = threading.Thread(target=self._worker_loop,
                                        name="codag-decomp-service",
                                        daemon=True)
        self._worker.start()

    # ------------------------------------------------------------- submit

    def submit(self, blob: fmt.CompressedBlob,
               device_out: bool = False) -> Future:
        """Enqueue one blob; returns a Future of the decoded array.

        ``device_out=True`` resolves the future with a device-resident jax
        array: decode + reassembly stay on device, and a cache hit hands
        out a device view of the cached host bytes."""
        return self.submit_many([blob], device_out=device_out)[0]

    def submit_many(self, blobs: Sequence[fmt.CompressedBlob],
                    device_out: bool = False) -> List[Future]:
        """Enqueue blobs ATOMICALLY: they enter the same window together
        (a window may grow past ``max_batch_blobs`` to keep a batch whole)."""
        if not blobs:
            return []
        now = time.perf_counter()
        reqs = [_Request(b, Future(), now,
                         blob_digest(b) if self._cache is not None else None,
                         device=device_out)
                for b in blobs]
        with self._lock:
            if self._closed:
                raise RuntimeError("DecompressionService is closed")
            # put under the lock so close() cannot interleave its sentinel
            # in front of us (anything after the sentinel would never drain).
            self._q.put(reqs)
        return [r.future for r in reqs]

    def submit_array(self, ca, device_out: bool = False) -> Future:
        """Enqueue a ``api.CompressedArray``; the future resolves to the
        recombined logical array (lo/hi planes joined for 8-byte dtypes)."""
        futs = self.submit_many(list(ca.blobs), device_out=device_out)
        out: Future = Future()
        pending = [len(futs)]
        lk = threading.Lock()
        combine = (fmt.combine_planes_device if device_out
                   else fmt.combine_planes)

        def _done(_):
            with lk:
                pending[0] -= 1
                if pending[0]:
                    return
            try:
                outs = [f.result() for f in futs]
                out.set_result(combine(outs, ca.orig_dtype, ca.orig_shape))
            except BaseException as e:  # propagate any blob failure
                out.set_exception(e)

        for f in futs:
            f.add_done_callback(_done)
        return out

    def submit_key(self, key: str, device_out: bool = False) -> Future:
        """Enqueue a blob BY STORE KEY: a decoded-cache miss for bytes that
        aren't even in host RAM resolves through the tiered store instead
        of failing — the store demand-pages the compressed payload
        (tier-1 host cache, else backend fetch on the store's pool), and
        the decode is submitted the moment the payload lands.  The payload
        may be a single ``CompressedBlob`` or a pickled
        ``api.CompressedArray`` (plane blobs recombined).  Requires
        ``store=`` at construction."""
        if self.store is None:
            raise RuntimeError("submit_key requires DecompressionService"
                               "(store=...): no lower tiers to page from")
        out: Future = Future()

        def _paged(fut: Future) -> None:
            try:
                obj = fut.result()
            except BaseException as e:     # missing key / corrupt payload
                out.set_exception(e)
                return
            try:
                inner = (self.submit_array(obj, device_out=device_out)
                         if hasattr(obj, "blobs")
                         else self.submit(obj, device_out=device_out))
            except BaseException as e:     # service closed, bad payload
                out.set_exception(e)
                return

            def _done(f: Future) -> None:
                try:
                    out.set_result(f.result())
                except BaseException as e:
                    out.set_exception(e)

            inner.add_done_callback(_done)

        self.store.fetch_async(key).add_done_callback(_paged)
        return out

    def decode(self, blob: fmt.CompressedBlob, device_out: bool = False):
        """Blocking single-blob convenience."""
        return self.submit(blob, device_out=device_out).result()

    def decode_arrays(self, cas: Sequence,
                      device_out: bool = False) -> List:
        """Blocking batch decode of ``CompressedArray``s.  All plane blobs of
        all arrays enter one window atomically, so the call costs exactly one
        dispatch per group key (same accounting as ``batch.BatchPlan``)."""
        flat = [b for ca in cas for b in ca.blobs]
        futs = self.submit_many(flat, device_out=device_out)
        outs = [f.result() for f in futs]
        combine = (fmt.combine_planes_device if device_out
                   else fmt.combine_planes)
        result, i = [], 0
        for ca in cas:
            n = len(ca.blobs)
            result.append(combine(outs[i:i + n], ca.orig_dtype,
                                  ca.orig_shape))
            i += n
        return result

    # ----------------------------------------------------------- lifecycle

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self, timeout: Optional[float] = None) -> bool:
        """Graceful shutdown: refuse new submits, drain every queued request
        (all outstanding futures resolve), then join the worker.

        Returns True once the worker has exited; False if the drain was
        still running when ``timeout`` elapsed (the shutdown keeps
        progressing in the background — call again to keep waiting)."""
        with self._lock:
            if not self._closed:
                self._closed = True
                self._q.put(_CLOSE)
        self._worker.join(timeout)
        return not self._worker.is_alive()

    def __enter__(self) -> "DecompressionService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # --------------------------------------------------------------- stats

    def stats(self) -> ServiceStats:
        with self._lock:
            lats = sorted(self._latencies)
            windows, blobs = self._windows, self._blobs
            dispatches = self._dispatches
            hits, misses = self._cache_hits, self._cache_misses
            errors = self._errors
            cache_bytes = self._cache.bytes if self._cache else 0
            device_dispatches = dict(self._device_dispatches)

        def pct(p: float) -> float:
            if not lats:
                return 0.0
            return lats[min(len(lats) - 1, int(p * (len(lats) - 1)))] * 1e3

        return ServiceStats(
            windows=windows, blobs=blobs, dispatches=dispatches,
            cache_hits=hits, cache_misses=misses, errors=errors,
            cache_bytes=cache_bytes,
            blobs_per_window=blobs / max(1, windows),
            dispatches_per_window=dispatches / max(1, windows),
            cache_hit_rate=hits / max(1, hits + misses),
            latency_p50_ms=pct(0.50), latency_p99_ms=pct(0.99),
            device_dispatches=device_dispatches)

    # -------------------------------------------------------------- worker

    def _worker_loop(self) -> None:
        while True:
            item = self._q.get()
            if item is _CLOSE:
                break
            window: List[_Request] = list(item)
            deadline = time.perf_counter() + self.max_delay_ms / 1e3
            closing = False
            while len(window) < self.max_batch_blobs:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    break
                try:
                    nxt = self._q.get(timeout=min(remaining,
                                                  self.idle_ms / 1e3))
                except queue.Empty:
                    break                        # queue idle — flush early
                if nxt is _CLOSE:
                    closing = True
                    break
                window.extend(nxt)
            try:
                self._process_window(window)
            except BaseException as e:   # the worker must survive anything:
                # a dead worker would hang every outstanding & future request
                for req in window:
                    if not req.future.done():
                        self._fail(req, e)
            if closing:
                break

    def _resolve(self, req: _Request, value: np.ndarray) -> None:
        with self._lock:
            self._latencies.append(time.perf_counter() - req.t_submit)
        try:
            req.future.set_result(value)
        except BaseException:            # future cancelled by the caller
            pass

    def _fail(self, req: _Request, exc: BaseException) -> None:
        with self._lock:
            self._errors += 1
            self._latencies.append(time.perf_counter() - req.t_submit)
        try:
            req.future.set_exception(exc)
        except BaseException:            # future cancelled by the caller
            pass

    def _process_window(self, window: List[_Request]) -> None:
        """One micro-batch: cache/dedupe pass, then one ``DecodePlan`` per
        group key — the same parse/group → stage → dispatch → reassemble
        pipeline every other entry path runs (``core.plan``), with the
        service's bucketing applied at plan build.  Building per group
        (rather than one window-wide plan) keeps failures isolated to the
        request (bad metadata) or the group (unlowerable blobs, decode
        error) that caused them.

        With ``devices`` configured, the plan's fused group dispatches are
        assigned round-robin across them — per-window multi-device
        scheduling; each group's table is staged on and decoded by its
        assigned device, and per-device dispatch counts land in
        ``ServiceStats.device_dispatches``.

        Results are served in the shape each request asked for: host
        ndarrays, or device-resident jax arrays (``device_out`` submits).
        The decode itself always stays on device; the host matrix is
        materialized at most ONCE per group, and only when some requester
        (or the cache) actually needs host bytes — an all-device window on
        a cache-less service performs zero device→host transfers."""
        import jax.numpy as jnp

        hits = misses = dispatches = 0
        device_dispatches: Dict[str, int] = {}
        # dedupe identical payloads in-window (by content digest with the
        # cache on, by blob identity without); order is preserved so the
        # plan's groups follow first-occurrence order.
        unique: "collections.OrderedDict[object, List[_Request]]" = \
            collections.OrderedDict()
        for req in window:
            try:
                fmt.group_key(req.blob)   # metadata sanity (bad codec etc.)
            except Exception as e:
                self._fail(req, e)
                continue
            dedupe_key = req.digest if req.digest is not None \
                else id(req.blob)
            cached = (self._cache.get(req.digest)
                      if self._cache is not None else None)
            if cached is not None:
                hits += 1
                # cache keeps host bytes; device requesters get a device
                # view of them (read-only, so no defensive copy needed)
                self._resolve(req, jnp.asarray(cached) if req.device
                              else cached.copy())
                continue
            misses += 1
            unique.setdefault(dedupe_key, []).append(req)

        # order reps into key groups (first-occurrence order, same as the
        # plan's parse/group stage); each group lowers to its OWN one-group
        # DecodePlan inside the per-group try, so an unlowerable group
        # (corrupt extras, impossible metadata) fails alone.
        by_key: "Dict[tuple, List[List[_Request]]]" = {}
        for reqs in unique.values():
            by_key.setdefault(fmt.group_key(reqs[0].blob), []).append(reqs)
        for key, group_reqs in by_key.items():
            device = None
            if self._devices:
                device = self._devices[self._rr % len(self._devices)]
                self._rr += 1
            need_host = self._cache is not None or any(
                not r.device for reqs in group_reqs for r in reqs)
            try:
                plan = plan_mod.DecodePlan.build(
                    [reqs[0].blob for reqs in group_reqs],
                    bucket=self.bucket_shapes,
                    bucket_floor=self.bucket_cols_floor)
                (g,) = plan.groups          # one key -> one fused group
                table_dev = plan.decode_group_device(
                    0, self.engine, device=device)
                table = (transfers.to_host(table_dev) if need_host
                         else None)
                dispatches += 1
                if device is not None:
                    k = str(device)
                    device_dispatches[k] = device_dispatches.get(k, 0) + 1
            except Exception as e:
                for reqs in group_reqs:
                    for req in reqs:
                        self._fail(req, e)
                continue
            for bid, row0 in zip(g.blob_ids, g.row_offsets):
                reqs = group_reqs[bid]
                blob = reqs[0].blob
                row = row0 + blob.num_chunks
                out = out_dev = None
                try:
                    if need_host:
                        out = fmt.reassemble(blob,
                                             table[row0:row].copy())
                    if any(r.device for r in reqs):
                        out_dev = fmt.reassemble_device(
                            blob, table_dev[row0:row])
                except Exception as e:   # bad per-blob metadata fails alone
                    for req in reqs:
                        self._fail(req, e)
                    continue
                if self._cache is not None and reqs[0].digest is not None:
                    self._cache.put(reqs[0].digest, out)   # put() copies
                first_host = True
                for req in reqs:
                    if req.device:
                        # jax arrays are immutable — duplicates share one
                        self._resolve(req, out_dev)
                    else:
                        self._resolve(req, out if first_host else out.copy())
                        first_host = False

        with self._lock:
            self._windows += 1
            self._blobs += len(window)
            self._dispatches += dispatches
            self._cache_hits += hits
            self._cache_misses += misses
            for k, v in device_dispatches.items():
                self._device_dispatches[k] = \
                    self._device_dispatches.get(k, 0) + v


# Process-wide default service (``api.decompress_many`` routes through it).
_default_service: Optional[DecompressionService] = None
_default_lock = threading.Lock()


def default_service() -> DecompressionService:
    """The lazily-created shared service.  Recreated transparently if a
    previous one was closed.  ``bucket_shapes`` AND the cache stay off here
    so one-shot ``api.decompress_many`` batches keep exact, call-local
    dispatch accounting (one dispatch per group, every time — no hidden
    process-wide memory of earlier calls); long-lived serving paths should
    construct their own service with bucketing + cache on."""
    global _default_service
    with _default_lock:
        if _default_service is None or _default_service.closed:
            _default_service = DecompressionService(bucket_shapes=False,
                                                    cache_bytes=0)
        return _default_service
