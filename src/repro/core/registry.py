"""Codec plugin registry — the framework claim of paper §IV-A, made literal.

CODAG's software contribution is that a decompressor is a *framework*: the
reader, group-table, and all-thread expansion machinery are shared, and a
codec author supplies only a header parse and a value expression.  This
module is the single place where a codec declares everything the rest of the
system needs:

  * ``encode``        — the host-side encoder (array -> ``CompressedBlob``)
  * ``decode``        — a ``kernels.harness.DecodeSpec`` covering the four
                        backends (xla / pallas / scalar / oracle)
  * ``needs_words``   — whether the device layout carries a uint32 word view
                        (bit-oriented codecs)
  * ``shared_extras`` — extras keys shared across blobs of a batch group
                        (everything else is a per-chunk table and is stacked
                        row-wise by ``format.concat_blobs``)
  * ``static_bits``   — the codec's static decode parameter, part of the
                        batch-scheduler group key
  * ``byte_stream``   — the codec consumes raw bytes (consumers may view any
                        dtype as uint8 before encoding, e.g. checkpoints)
  * ``plane_decompose_64`` — 8-byte dtypes should be split into lo/hi uint32
                        planes before encoding (keeps runs / value locality)
  * ``demo_data``     — a generator of codec-appropriate compressible data
                        (drives the bench matrices and smoke tests)
  * ``count_groups``  — optional host-side header walk counting compressed
                        groups in one chunk row (Table V symbol lengths)

``ops.decode``, ``encoders.compress``, ``format.group_key`` /
``concat_blobs`` / ``to_device``, the batch scheduler, checkpointing, and
the benchmarks all dispatch through this table; none of them name a codec.

Adding a codec == writing one plugin module that calls ``register()`` (see
``kernels/dbp.py`` for the canonical example) and listing it in
``_PLUGINS`` (or importing it yourself before use).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np


def _no_bits(blob: Any) -> int:
    return 0


@dataclasses.dataclass(frozen=True)
class Codec:
    """Everything one codec contributes to the framework."""

    name: str
    # (arr, chunk_bytes, *, bits=None) -> format.CompressedBlob
    encode: Callable[..., Any]
    # kernels.harness.DecodeSpec (opaque here: core must not import kernels)
    decode: Any
    needs_words: bool = False
    shared_extras: Tuple[str, ...] = ()
    byte_stream: bool = False
    plane_decompose_64: bool = False
    static_bits: Callable[[Any], int] = _no_bits
    # (n_elems, rng) -> np.ndarray of codec-appropriate compressible data
    demo_data: Optional[Callable[[int, Any], np.ndarray]] = None
    # (comp_row: np.ndarray, width: int) -> group count for one chunk
    count_groups: Optional[Callable[[np.ndarray, int], int]] = None


_REGISTRY: Dict[str, Codec] = {}

# Built-in plugin modules; each registers its Codec on import.  Third-party
# codecs simply call register() from their own module instead.
_PLUGINS: Dict[str, str] = {
    "rle_v1": "repro.kernels.rle_v1",
    "rle_v2": "repro.kernels.rle_v2",
    "tdeflate": "repro.kernels.tdeflate",
    "bitpack": "repro.kernels.bitpack",
    "dbp": "repro.kernels.dbp",
    "huffman": "repro.kernels.huffman",
    "lzss": "repro.kernels.lzss",
}


def register(codec: Codec) -> Codec:
    """Register (or replace) a codec. Returns it, so plugins can keep a ref."""
    _REGISTRY[codec.name] = codec
    return codec


def get(name: str) -> Codec:
    """Look up a codec, lazily importing its built-in plugin module."""
    codec = _REGISTRY.get(name)
    if codec is None and name in _PLUGINS:
        importlib.import_module(_PLUGINS[name])
        codec = _REGISTRY.get(name)
    if codec is None:
        raise ValueError(
            f"unknown codec {name!r}; registered: {sorted(set(_REGISTRY) | set(_PLUGINS))}")
    return codec


def names() -> Tuple[str, ...]:
    """All registered codec names (built-in plugins force-loaded first)."""
    for name in _PLUGINS:
        if name not in _REGISTRY:
            importlib.import_module(_PLUGINS[name])
    return tuple(_REGISTRY)
