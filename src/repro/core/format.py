"""CODAG-JAX chunked container format (CJC).

Mirrors the indexed-chunk layout of modern compressed data formats (ORC
stripes / Parquet pages, paper §II-B): the uncompressed stream is split into
fixed-size chunks, each chunk is compressed independently, and an index of
per-chunk offsets/sizes enables chunk-parallel decompression.

TPU adaptation: instead of a byte stream + offset list (pointer-chasing), the
device layout is *rectangular* — a dense ``(num_chunks, max_comp_bytes)``
uint8 matrix plus per-chunk length vectors — so a Pallas grid cell (the
"warp" analog, DESIGN.md §2) can DMA its chunk with a plain BlockSpec.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, Optional

import numpy as np

from repro.core import registry

DEFAULT_CHUNK_BYTES = 128 * 1024  # 128 KiB, same as the paper's evaluation

# Codec registry keys (the authoritative per-codec metadata lives in
# ``repro.core.registry``; these are just the canonical name constants).
RLE_V1 = "rle_v1"
RLE_V2 = "rle_v2"
TDEFLATE = "tdeflate"
BITPACK = "bitpack"
DBP = "dbp"
CODECS = (RLE_V1, RLE_V2, TDEFLATE, BITPACK, DBP)

# Widths supported on device. 8-byte dtypes are transparently viewed as two
# 4-byte lanes (TPUs have no 64-bit vector type; runs of u64 are runs of the
# u32 pair view, so RLE still applies).
SUPPORTED_WIDTHS = (1, 2, 4)


def _as_bytes_view(arr: np.ndarray) -> tuple[np.ndarray, int, np.dtype]:
    """Flatten ``arr`` into a (bytes_view, elem_width, device_dtype) triple."""
    a = np.ascontiguousarray(arr)
    width = a.dtype.itemsize
    if width == 8:  # view u64/f64/i64 as u32 pairs
        a = a.view(np.uint32)
        width = 4
    if width not in SUPPORTED_WIDTHS:
        raise ValueError(f"unsupported element width {width}")
    dev_dtype = {1: np.uint8, 2: np.uint16, 4: np.uint32}[width]
    return a.reshape(-1).view(dev_dtype), width, np.dtype(dev_dtype)


@dataclasses.dataclass
class CompressedBlob:
    """Host-side compressed container (numpy)."""

    codec: str
    width: int                    # bytes per element (1/2/4)
    chunk_elems: int              # uncompressed elements per full chunk
    total_elems: int              # total uncompressed elements
    orig_dtype: str               # dtype string of the original array
    orig_shape: tuple             # original shape (for reconstruction)
    comp: np.ndarray              # (num_chunks, max_comp_bytes) uint8
    comp_lens: np.ndarray         # (num_chunks,) int32 — valid bytes per row
    out_lens: np.ndarray          # (num_chunks,) int32 — elements per chunk
    extras: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)

    @property
    def num_chunks(self) -> int:
        return int(self.comp.shape[0])

    @property
    def compressed_bytes(self) -> int:
        """True compressed payload size (index + per-chunk bytes), no padding."""
        extra = sum(int(v.nbytes) for k, v in self.extras.items()
                    if k.startswith("hdr_"))
        return int(self.comp_lens.sum()) + extra

    @property
    def uncompressed_bytes(self) -> int:
        return self.total_elems * self.width

    @property
    def ratio(self) -> float:
        """Compression ratio as reported in the paper (comp/uncomp, Table V)."""
        return self.compressed_bytes / max(1, self.uncompressed_bytes)

    def to_device(self, pad_comp_to: Optional[int] = None) -> Dict[str, Any]:
        """Return a pytree of device-layout numpy arrays (jnp-convertible).

        ``pad_comp_to`` optionally rounds max_comp_bytes up (e.g. to a lane
        multiple) so BlockSpecs tile cleanly.
        """
        comp = self.comp
        want = comp.shape[1]
        # Pad so byte loads 4-at-a-time and bitstream peeks never run off the
        # end (Alg. 1's "input buffer holds at least two cache lines").
        want = max(want + 8, pad_comp_to or 0)
        want = int(np.ceil(want / 128) * 128)  # lane-align
        if want != comp.shape[1]:
            comp = np.zeros((comp.shape[0], want), np.uint8)
            comp[:, : self.comp.shape[1]] = self.comp
        out = {
            "comp": comp,
            "comp_lens": self.comp_lens.astype(np.int32),
            "out_lens": self.out_lens.astype(np.int32),
        }
        if registry.get(self.codec).needs_words:
            # bit codecs consume uint32 words (input_stream funnel loads)
            out["comp_words"] = np.ascontiguousarray(comp).view(np.uint32)
        out.update(self.extras)
        return out


def group_key(blob: "CompressedBlob") -> tuple:
    """Batching key: blobs with equal keys share one decode dispatch.

    Everything static to ``ops.decode`` must be in the key — codec, element
    width, chunk geometry, and the codec's own static decode parameter
    (``registry.Codec.static_bits``, e.g. bitpack's bit width).
    """
    bits = registry.get(blob.codec).static_bits(blob)
    return (blob.codec, blob.width, blob.chunk_elems, bits)


def concat_blobs(blobs: list["CompressedBlob"]) -> "CompressedBlob":
    """Merge same-key blobs into one flat chunk table.

    The result is a valid ``CompressedBlob`` whose rows are the chunks of
    every input blob in order, so a single ``ops.decode`` treats each chunk
    from each blob as an independent stream (the CODAG provisioning move:
    one saturated launch instead of N under-provisioned ones).  Callers
    scatter the (total_chunks, chunk_elems) output back per blob by row
    ranges; the merged blob's ``orig_shape`` is a flat placeholder.

    Memory note: every merged row is padded to the group-wide max compressed
    row length, so grouping a near-incompressible blob with well-compressed
    ones inflates the host table toward num_chunks * chunk_bytes.  Callers
    that care bound the batch (``pipeline.decoded_shards(window=)``); if it
    bites at checkpoint scale, sub-bucket groups by comp-row magnitude at
    the cost of extra dispatches.
    """
    if not blobs:
        raise ValueError("concat_blobs needs at least one blob")
    key = group_key(blobs[0])
    for b in blobs[1:]:
        if group_key(b) != key:
            raise ValueError(f"group key mismatch: {group_key(b)} != {key}")
    if len(blobs) == 1:
        return blobs[0]
    max_len = max(b.comp.shape[1] for b in blobs)
    total_chunks = sum(b.num_chunks for b in blobs)
    comp = np.zeros((total_chunks, max_len), np.uint8)
    row = 0
    for b in blobs:
        comp[row:row + b.num_chunks, : b.comp.shape[1]] = b.comp
        row += b.num_chunks
    extras: Dict[str, np.ndarray] = {}
    shared = registry.get(blobs[0].codec).shared_extras
    for k, v0 in blobs[0].extras.items():
        if k in shared:      # group-wide scalars (e.g. bitpack_bits)
            extras[k] = v0
        else:                # per-chunk tables: stack rows
            extras[k] = np.concatenate([b.extras[k] for b in blobs], axis=0)
    total_elems = sum(b.total_elems for b in blobs)
    return CompressedBlob(
        codec=blobs[0].codec,
        width=blobs[0].width,
        chunk_elems=blobs[0].chunk_elems,
        total_elems=int(total_elems),
        orig_dtype=blobs[0].orig_dtype,
        orig_shape=(int(total_elems),),
        comp=comp,
        comp_lens=np.concatenate([b.comp_lens for b in blobs]).astype(np.int32),
        out_lens=np.concatenate([b.out_lens for b in blobs]).astype(np.int32),
        extras=extras,
    )


def pad_table_rows(table: "CompressedBlob", target_rows: int) -> "CompressedBlob":
    """Pad a chunk table to ``target_rows`` with zero-length trailing chunks.

    Padding rows have ``comp_lens == out_lens == 0`` — every decode body
    exits immediately on them, the same convention the engine's block mode
    relies on — and sit at the END of the table so callers' row-range
    scatter is unaffected.  Used by the service's pow2 shape bucketing and
    by the sharded executor's per-device uniform padding (every device of a
    mesh axis must decode the same local row count).
    """
    rows = table.num_chunks
    if target_rows < rows:
        raise ValueError(f"cannot pad {rows} rows down to {target_rows}")
    if target_rows == rows:
        return table
    pad = target_rows - rows
    comp = np.zeros((target_rows, table.comp.shape[1]), np.uint8)
    comp[:rows] = table.comp
    shared = registry.get(table.codec).shared_extras
    extras = {}
    for k, v in table.extras.items():
        if k in shared or v.shape[:1] != (rows,):
            extras[k] = v                    # group-wide scalar/table
        else:                                # per-chunk rows: pad with zeros
            extras[k] = np.concatenate(
                [v, np.zeros((pad,) + v.shape[1:], v.dtype)], axis=0)
    return dataclasses.replace(
        table, comp=comp,
        comp_lens=np.concatenate(
            [table.comp_lens, np.zeros(pad, np.int32)]).astype(np.int32),
        out_lens=np.concatenate(
            [table.out_lens, np.zeros(pad, np.int32)]).astype(np.int32),
        extras=extras)


def _next_pow2(n: int) -> int:
    return 1 << max(0, n - 1).bit_length() if n > 1 else 1


def pad_table_to_bucket(table: "CompressedBlob",
                        cols_floor: Optional[int] = None) -> "CompressedBlob":
    """Pad a merged chunk table to power-of-two row/column buckets.

    Every micro-batch window fuses a different set of blobs, so the merged
    table's ``(num_chunks, max_comp_bytes)`` shape is fresh almost every
    window — and each fresh shape is a new XLA compile.  Padding rows with
    zero-length chunks (:func:`pad_table_rows`) and columns with zero bytes
    buckets the jit cache by ``(group key, pow2 rows, pow2 cols)``: after a
    handful of windows the steady state is compile-free.

    ``cols_floor`` is the minimum column bucket — the knob trading padding
    waste (small tables inflated to the floor) against jit-cache pressure
    (more distinct shapes below it).  Explicit values win; ``None``
    consults the tuned-defaults table for this blob's (codec, width) on
    the current device (``core.tuning``), and with no tuning entry the
    historical floor of 128 applies unchanged.
    """
    if cols_floor is None:
        from repro.core import tuning
        cols_floor = tuning.bucket_cols_floor(table.codec, table.width)
    floor = 128 if cols_floor is None else int(cols_floor)
    padded = pad_table_rows(table, _next_pow2(table.num_chunks))
    cols = int(padded.comp.shape[1])
    target_cols = max(floor, _next_pow2(cols))
    if target_cols == cols:
        return padded
    comp = np.zeros((padded.num_chunks, target_cols), np.uint8)
    comp[:, :cols] = padded.comp
    return dataclasses.replace(padded, comp=comp)


def blob_digest(blob: "CompressedBlob") -> str:
    """Content hash of a compressed blob — equal digests decode identically.

    Covers everything the decode output depends on: codec + static decode
    metadata, the dense comp matrix (padding is all-zeros by construction,
    so it is deterministic), the length vectors, and every extras table.
    Used as the service cache key, the plan executor's staging cache key,
    and by the golden-vector conformance suite as the committed encoder
    fingerprint.
    """
    h = hashlib.blake2b(digest_size=16)
    h.update(f"{blob.codec}|{blob.width}|{blob.chunk_elems}|"
             f"{blob.total_elems}|{blob.orig_dtype}|{blob.orig_shape}"
             .encode())
    h.update(np.ascontiguousarray(blob.comp_lens, np.int64).tobytes())
    h.update(np.ascontiguousarray(blob.out_lens, np.int64).tobytes())
    h.update(np.ascontiguousarray(blob.comp).tobytes())
    for k in sorted(blob.extras):
        v = np.ascontiguousarray(blob.extras[k])
        h.update(f"|{k}|{v.dtype}|{v.shape}|".encode())
        h.update(v.tobytes())
    return h.hexdigest()


def combine_planes(outs: list, orig_dtype: str, orig_shape: tuple) -> np.ndarray:
    """Recombine decoded plane blobs into one logical array.

    One blob is the common case (``reassemble`` already restored
    dtype/shape); two blobs are the lo/hi uint32 planes of an 8-byte dtype
    (``api.compress`` plane decomposition).
    """
    if len(outs) == 1:
        return outs[0]
    lo, hi = outs
    u64 = (lo.reshape(-1).astype(np.uint64)
           | (hi.reshape(-1).astype(np.uint64) << np.uint64(32)))
    return u64.view(np.dtype(orig_dtype)).reshape(orig_shape)


def chunk_array(arr: np.ndarray, chunk_bytes: int = DEFAULT_CHUNK_BYTES):
    """Split ``arr`` into fixed-size element chunks (last may be short)."""
    flat, width, dev_dtype = _as_bytes_view(arr)
    chunk_elems = max(1, chunk_bytes // width)
    n = flat.shape[0]
    num_chunks = max(1, (n + chunk_elems - 1) // chunk_elems)
    chunks = [flat[i * chunk_elems : min((i + 1) * chunk_elems, n)]
              for i in range(num_chunks)]
    return chunks, chunk_elems, width, dev_dtype


def build_blob(
    codec: str,
    arr: np.ndarray,
    encoded: list[bytes],
    chunk_elems: int,
    width: int,
    extras: Optional[Dict[str, np.ndarray]] = None,
    total_elems: Optional[int] = None,
) -> CompressedBlob:
    """Assemble the rectangular device layout from per-chunk byte strings."""
    if total_elems is None:
        flat, _, _ = _as_bytes_view(arr)
        total_elems = flat.shape[0]
    n = total_elems
    num_chunks = len(encoded)
    max_len = max(len(e) for e in encoded) if encoded else 1
    comp = np.zeros((num_chunks, max_len), np.uint8)
    comp_lens = np.zeros((num_chunks,), np.int32)
    out_lens = np.zeros((num_chunks,), np.int32)
    for i, e in enumerate(encoded):
        comp[i, : len(e)] = np.frombuffer(e, np.uint8)
        comp_lens[i] = len(e)
        out_lens[i] = min(chunk_elems, n - i * chunk_elems)
    return CompressedBlob(
        codec=codec,
        width=width,
        chunk_elems=chunk_elems,
        total_elems=int(n),
        orig_dtype=str(arr.dtype),
        orig_shape=tuple(arr.shape),
        comp=comp,
        comp_lens=comp_lens,
        out_lens=out_lens,
        extras=extras or {},
    )


def reassemble(blob: CompressedBlob, chunks_out: np.ndarray) -> np.ndarray:
    """Stitch decoded (num_chunks, chunk_elems) back to the original array."""
    flat = np.ascontiguousarray(chunks_out.reshape(-1)[: blob.total_elems])
    return flat.view(np.dtype(blob.orig_dtype)).reshape(blob.orig_shape)


# --------------------------------------------------------------------------
# Device-side reassembly (the ISSUE-4 tentpole: a decoded blob is born,
# reassembled, and consumed on device — no host round trip).
# --------------------------------------------------------------------------


def _require_x64(dtype: np.dtype) -> None:
    import jax
    if dtype.itemsize == 8 and not jax.config.jax_enable_x64:
        raise ValueError(
            f"device-resident reassembly to {dtype} needs 64-bit jax types; "
            "enable them (jax.experimental.enable_x64() or "
            "jax_enable_x64=True) or use the host path (reassemble / "
            "combine_planes)")


def device_view(flat, dtype, shape=None):
    """Device analog of ``flat.view(dtype).reshape(shape)`` — a pure bitcast
    reinterpretation, jit-compatible.  ``flat`` is a 1-D jax array; widening
    views (e.g. uint8 bytes -> float32, uint32 pairs -> uint64) regroup
    ``itemsize_ratio`` consecutive elements per output element."""
    import jax.numpy as jnp
    from jax import lax
    od = np.dtype(dtype)
    _require_x64(od)
    cur = np.dtype(flat.dtype)
    if od == cur:
        out = flat
    elif od.itemsize == cur.itemsize:
        out = lax.bitcast_convert_type(flat, od)
    elif od.itemsize > cur.itemsize:
        k = od.itemsize // cur.itemsize
        if flat.shape[0] % k:
            raise ValueError(f"{flat.shape[0]} {cur} elements do not view "
                             f"evenly as {od}")
        out = lax.bitcast_convert_type(flat.reshape(-1, k), od)
    else:
        out = lax.bitcast_convert_type(flat, od).reshape(-1)
    return out.reshape(shape if shape is not None else (-1,))


def reassemble_indices(blob: CompressedBlob) -> Optional[np.ndarray]:
    """Precomputed gather for device reassembly, or ``None`` when trivial.

    Returns the flat source index per output element — output position ``p``
    reads ``chunks_out.reshape(-1)[idx[p]]`` — derived from the per-row
    destination offsets (exclusive cumsum of ``out_lens``).  For the standard
    layout (every chunk full except a trailing tail, the ``build_blob``
    invariant) the decode matrix is already contiguous and a reshape+trim
    suffices, so ``None`` is returned and no index table needs staging.
    """
    out_lens = np.asarray(blob.out_lens, np.int64)
    n = len(out_lens)
    expect = np.clip(blob.total_elems - np.arange(n) * blob.chunk_elems,
                     0, blob.chunk_elems)
    if np.array_equal(out_lens, expect):
        return None               # contiguous: reshape(-1)[:total] is exact
    dest = np.concatenate([[0], np.cumsum(out_lens)])   # per-row dest offsets
    if dest[-1] != blob.total_elems:
        raise ValueError(f"out_lens sum {dest[-1]} != total {blob.total_elems}")
    p = np.arange(blob.total_elems, dtype=np.int64)
    row = np.searchsorted(dest, p, side="right") - 1
    return (row * blob.chunk_elems + (p - dest[row])).astype(np.int32)


def reassemble_rows_device(table, *, row0: int, num_chunks: int,
                           total_elems: int, orig_dtype: str,
                           orig_shape: tuple, indices=None,
                           transformed: bool = False):
    """Jit-compatible row-range reassembly from a fused group table.

    Slices ``num_chunks`` rows starting at ``row0`` out of the decoded
    ``(group_chunks, chunk_elems)`` device matrix and stitches them into
    the blob's original array, all as traced device ops (zero host syncs
    when called inside jit / with pre-staged ``indices``).

    ``indices``: the precomputed per-row-destination gather from
    :func:`reassemble_indices`, or None for the contiguous reshape+trim
    fast path.  ``transformed=True`` marks output of a fused decode
    epilogue — element values (and dtype) are the epilogue's, so the
    original-dtype bitcast is skipped and only the trim + reshape applies.
    """
    import jax.numpy as jnp
    from jax import lax
    rows = lax.slice_in_dim(table, row0, row0 + num_chunks)
    flat = jnp.reshape(rows, (-1,))
    if indices is None:
        flat = lax.slice_in_dim(flat, 0, total_elems)
    else:
        flat = flat[indices] if total_elems else flat[:0]
    if transformed:
        n = int(np.prod(orig_shape)) if orig_shape else 1
        return flat.reshape(orig_shape if n == total_elems else (-1,))
    return device_view(flat, orig_dtype, orig_shape)


def reassemble_device(blob: CompressedBlob, chunks_out, *,
                      indices: Optional[Any] = None,
                      transformed: bool = False):
    """Device analog of :func:`reassemble`: stitch the decoded
    ``(num_chunks, chunk_elems)`` jax matrix back to the original array
    without leaving the device (jit-compatible; bit-exact vs the host path).

    ``indices``: optional pre-staged gather from :func:`reassemble_indices`
    (e.g. carried by a ``BatchPlan``); by default it is derived here from
    the blob's host metadata.
    """
    if indices is None:
        indices = reassemble_indices(blob)
    return reassemble_rows_device(
        chunks_out, row0=0, num_chunks=blob.num_chunks,
        total_elems=blob.total_elems, orig_dtype=blob.orig_dtype,
        orig_shape=tuple(blob.orig_shape), indices=indices,
        transformed=transformed)


def combine_planes_device(outs: list, orig_dtype: str, orig_shape: tuple):
    """Device analog of :func:`combine_planes` (jit-compatible).

    Two plane blobs are the lo/hi uint32 halves of an 8-byte dtype; their
    recombination is a lane interleave + bitcast, which needs 64-bit jax
    types enabled (a consumer that cannot hold a 64-bit device array has no
    use for a device-resident one).
    """
    import jax.numpy as jnp
    from jax import lax
    if len(outs) == 1:
        return outs[0]
    _require_x64(np.dtype(orig_dtype))
    lo, hi = outs
    pair = jnp.stack([lo.reshape(-1).astype(jnp.uint32),
                      hi.reshape(-1).astype(jnp.uint32)], axis=-1)
    u64 = lax.bitcast_convert_type(pair, jnp.uint64)
    return device_view(u64, orig_dtype, orig_shape)
