"""Tiered blob store: demand-paged compressed blobs with async prefetch.

CODAG's characterization says GPU decompression is COMPUTE-bound (§V) —
which means storage I/O for the compressed bytes can be hidden entirely
behind in-flight decode, the overlap Sitaridi et al. exploit by pipelining
transfer against decompression.  Until now the repo assumed every
compressed blob already sat in host RAM; this module removes that
assumption with a three-tier store:

    tier 0 — HBM decoded-blob cache: the ``DecompressionService``'s
             digest-keyed LRU (attached via ``DecompressionService(store=)``;
             its hit/miss counters surface in :meth:`TieredBlobStore.stats`).
    tier 1 — host compressed-blob cache: a byte-budgeted LRU with
             WATERMARK eviction — admits until the high byte-mark, then
             evicts LRU entries down to the low byte-mark (hysteresis: one
             oversized window doesn't cause per-insert eviction churn).
    tier 2 — a :class:`BlobBackend`: the disk filesystem
             (:class:`FilesystemBackend`, atomic writes) or any S3-style
             object store implementing ``get/put/size/list_keys/delete``.

Demand paging: :meth:`TieredBlobStore.get` serves tier 1 hits, joins an
already-in-flight fetch, or pages the blob in from the backend.
:meth:`TieredBlobStore.prefetch` schedules fetches on a small thread pool
without blocking; :meth:`TieredBlobStore.stream_windows` is the overlap
loop every streaming consumer uses —

    while the consumer decodes window i (DecodePlan stage + dispatch),
    window i+1..i+lookahead's blobs are being fetched by the pool;
    consumed windows are released back under the byte budget.

so a checkpoint restore / token-shard epoch larger than host memory runs
with bounded resident bytes and the backend I/O hidden behind decode
(``benchmarks/store.py`` measures the overlap efficiency).

    store = TieredBlobStore(FilesystemBackend(root), host_budget_bytes=1 << 28)
    ca = store.get("step_1/layer0.npy.blob")      # demand-page (pickle)
    store.prefetch(keys)                          # async, non-blocking
    for window in store.stream_windows(keys, window=8):
        ...decode window...                       # i+1 already in flight
    store.stats()                                 # per-tier hits/misses/
                                                  # evictions/bytes in flight
"""
from __future__ import annotations

import collections
import dataclasses
import os
import pickle
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from pathlib import Path
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence


class StoreError(RuntimeError):
    """A blob could not be read or deserialized from the backend."""


class BlobMissing(StoreError, KeyError):
    """The backend has no (complete) payload under the requested key."""


# --------------------------------------------------------------------------
# tier 2 — backends
# --------------------------------------------------------------------------


class BlobBackend:
    """S3-style object-store interface for compressed blob payloads.

    Implementations must make ``put`` ATOMIC: a reader never observes a
    partially-written payload under a published key (crash mid-put leaves
    garbage that ``get``/``list_keys`` ignore).  Keys are ``/``-separated
    strings; payloads are opaque bytes.
    """

    def get(self, key: str) -> bytes:
        raise NotImplementedError

    def put(self, key: str, data: bytes) -> None:
        raise NotImplementedError

    def size(self, key: str) -> Optional[int]:
        """Payload size in bytes, or None if unknown/absent (used for the
        bytes-in-flight gauge; a backend may answer cheaply via metadata)."""
        raise NotImplementedError

    def list_keys(self) -> List[str]:
        raise NotImplementedError

    def delete(self, key: str) -> None:
        raise NotImplementedError


class FilesystemBackend(BlobBackend):
    """Disk tier rooted at a directory; one file per key.

    * ``put`` writes ``<key>.tmp`` then ``os.replace``s it into place — a
      crash mid-write leaves only the ``.tmp``, which every read path
      ignores, so a published key is always a complete payload.
    * ``read_delay_s`` injects a per-``get`` latency, standing in for an
      object store's RTT — the store benchmark uses it to make the
      I/O-hiding measurement meaningful on fast local disks.
    """

    def __init__(self, root, *, read_delay_s: float = 0.0):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.read_delay_s = float(read_delay_s)

    def _path(self, key: str) -> Path:
        p = (self.root / key).resolve()
        if self.root.resolve() not in p.parents and p != self.root.resolve():
            raise StoreError(f"key {key!r} escapes the backend root")
        return p

    def get(self, key: str) -> bytes:
        if self.read_delay_s:
            time.sleep(self.read_delay_s)
        p = self._path(key)
        try:
            return p.read_bytes()
        except FileNotFoundError:
            raise BlobMissing(key) from None

    def put(self, key: str, data: bytes) -> None:
        p = self._path(key)
        p.parent.mkdir(parents=True, exist_ok=True)
        tmp = p.with_name(p.name + ".tmp")
        tmp.write_bytes(data)
        os.replace(tmp, p)            # atomic publish; crash leaves only .tmp

    def size(self, key: str) -> Optional[int]:
        try:
            return self._path(key).stat().st_size
        except FileNotFoundError:
            return None

    def list_keys(self) -> List[str]:
        return sorted(
            str(p.relative_to(self.root))
            for p in self.root.rglob("*")
            if p.is_file() and not p.name.endswith(".tmp"))

    def delete(self, key: str) -> None:
        try:
            self._path(key).unlink()
        except FileNotFoundError:
            pass


class MemoryBackend(BlobBackend):
    """Dict-backed stub with the object-store interface (tests, and the
    seam where a real S3 client would plug in)."""

    def __init__(self, *, read_delay_s: float = 0.0):
        self._data: Dict[str, bytes] = {}
        self._lock = threading.Lock()
        self.read_delay_s = float(read_delay_s)

    def get(self, key: str) -> bytes:
        if self.read_delay_s:
            time.sleep(self.read_delay_s)
        with self._lock:
            try:
                return self._data[key]
            except KeyError:
                raise BlobMissing(key) from None

    def put(self, key: str, data: bytes) -> None:
        with self._lock:
            self._data[key] = bytes(data)

    def size(self, key: str) -> Optional[int]:
        with self._lock:
            d = self._data.get(key)
        return None if d is None else len(d)

    def list_keys(self) -> List[str]:
        with self._lock:
            return sorted(self._data)

    def delete(self, key: str) -> None:
        with self._lock:
            self._data.pop(key, None)


# --------------------------------------------------------------------------
# stats
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StoreStats:
    """Per-tier snapshot (cumulative counters, point-in-time gauges)."""

    # tier 1 — host compressed cache
    host_hits: int            # gets served without issuing a backend fetch
    host_misses: int          # backend fetches issued (by get OR prefetch)
    host_evictions: int       # watermark evictions (budget pressure)
    host_released: int        # consumed-window releases (stream_windows)
    host_bytes: int           # resident compressed bytes (gauge)
    host_entries: int
    # tier 2 — backend
    backend_fetches: int      # completed backend reads
    backend_bytes_fetched: int
    inflight_fetches: int     # gauge
    bytes_in_flight: int      # gauge (backend.size of keys being fetched)
    # tier 0 — decoded cache of the attached DecompressionService
    decoded_hits: int = 0
    decoded_misses: int = 0
    decoded_bytes: int = 0

    @property
    def host_hit_rate(self) -> float:
        return self.host_hits / max(1, self.host_hits + self.host_misses)


# --------------------------------------------------------------------------
# the tiered store
# --------------------------------------------------------------------------


def _default_loads(data: bytes) -> Any:
    try:
        return pickle.loads(data)
    except Exception as e:
        raise StoreError(f"corrupt blob payload: {e}") from e


class TieredBlobStore:
    """Demand-paging compressed-blob store with async prefetch; see module
    docstring for the tier layout.

    Parameters
    ----------
    backend:            the tier-2 :class:`BlobBackend`.
    host_budget_bytes:  tier-1 high byte-mark.  Admitting past it evicts
                        LRU entries down to ``low_watermark * budget``.
    low_watermark:      eviction hysteresis target as a fraction of the
                        budget (0 < low <= 1).
    prefetch_workers:   thread-pool width for async paging; also the
                        fan-out of one window's parallel fetches.
    loads / dumps:      (de)serializers between payload bytes and blob
                        objects.  Defaults: pickle (what ``checkpoint``
                        writes); ``loads`` failures surface as
                        :class:`StoreError`.

    Sizes are accounted in PAYLOAD bytes (what the backend stores), so the
    budget bounds resident compressed bytes regardless of the deserialized
    object's layout.
    """

    def __init__(self, backend: BlobBackend, *,
                 host_budget_bytes: int = 256 << 20,
                 low_watermark: float = 0.8,
                 prefetch_workers: int = 4,
                 loads: Callable[[bytes], Any] = _default_loads,
                 dumps: Callable[[Any], bytes] = pickle.dumps):
        if not 0.0 < low_watermark <= 1.0:
            raise ValueError("low_watermark must be in (0, 1]")
        self.backend = backend
        self.host_budget_bytes = int(host_budget_bytes)
        self.low_watermark = float(low_watermark)
        self._loads = loads
        self._dumps = dumps
        self._pool = ThreadPoolExecutor(
            max_workers=max(1, int(prefetch_workers)),
            thread_name_prefix="codag-store-prefetch")
        self._lock = threading.Lock()
        # key -> (obj, payload_bytes); OrderedDict = LRU order
        self._entries: "collections.OrderedDict[str, tuple]" = \
            collections.OrderedDict()
        self._bytes = 0
        self._inflight: Dict[str, Future] = {}
        self._inflight_bytes: Dict[str, int] = {}
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        self._released = 0
        self._fetches = 0
        self._fetched_bytes = 0
        self._tier0 = None            # attached DecompressionService
        self._closed = False

    # ------------------------------------------------------------ tier 0

    def attach_tier0(self, service) -> None:
        """Register the ``DecompressionService`` whose decoded-blob LRU is
        this store's tier 0 (``DecompressionService(store=)`` calls this);
        its cache counters then appear in :meth:`stats`."""
        self._tier0 = service

    # ------------------------------------------------------------- paging

    def get(self, key: str) -> Any:
        """Blocking demand-page: tier-1 hit, join of an in-flight fetch, or
        a synchronous backend read (counted as a miss)."""
        fut = self._lookup_or_fetch(key)
        if fut is None:
            with self._lock:
                obj, _ = self._entries[key]
            return obj
        return fut.result()

    def fetch_async(self, key: str) -> Future:
        """Future of the demand-paged object; resolves immediately on a
        tier-1 hit.  The service's ``submit_key`` chains decode onto it."""
        fut = self._lookup_or_fetch(key)
        if fut is not None:
            return fut
        done: Future = Future()
        with self._lock:
            obj, _ = self._entries[key]
        done.set_result(obj)
        return done

    def prefetch(self, keys: Sequence[str]) -> None:
        """Schedule async fetches for every key not already resident or in
        flight.  Never blocks; failures surface when ``get`` joins the
        fetch (or are dropped if nobody ever asks)."""
        for key in keys:
            self._lookup_or_fetch(key, sync=False)

    def _lookup_or_fetch(self, key: str,
                         sync: bool = True) -> Optional[Future]:
        """Resolve ``key`` against tier 1 / the in-flight table, issuing a
        backend fetch on a true miss.  Returns None on a resident hit, a
        Future otherwise.  ``sync=False`` (prefetch) never counts hits."""
        with self._lock:
            if self._closed:
                raise StoreError("TieredBlobStore is closed")
            if key in self._entries:
                if sync:
                    self._entries.move_to_end(key)
                    self._hits += 1
                return None
            fut = self._inflight.get(key)
            if fut is not None:
                if sync:
                    self._hits += 1   # no new fetch issued — the page is
                return fut            # already on its way in
            self._misses += 1
            fut = Future()
            self._inflight[key] = fut
            size = None
        try:
            size = self.backend.size(key)
        except Exception:
            size = None
        with self._lock:
            self._inflight_bytes[key] = int(size or 0)
        self._pool.submit(self._fetch_into, key, fut)
        return fut

    def _fetch_into(self, key: str, fut: Future) -> None:
        try:
            data = self.backend.get(key)
            obj = self._loads(data)
        except BaseException as e:
            with self._lock:
                self._inflight.pop(key, None)
                self._inflight_bytes.pop(key, None)
            fut.set_exception(e)
            return
        with self._lock:
            self._inflight.pop(key, None)
            self._inflight_bytes.pop(key, None)
            self._fetches += 1
            self._fetched_bytes += len(data)
            self._admit(key, obj, len(data))
        fut.set_result(obj)

    def _admit(self, key: str, obj: Any, nbytes: int) -> None:
        """Insert under the watermark policy (caller holds the lock).

        Every fetched page is admitted — a blob the consumer is about to
        use must be resident whatever its size, so the budget is enforced
        by evicting OLDER entries down to the low mark (never the entry
        just inserted).  A single entry larger than the whole budget is
        therefore the one case resident bytes can exceed it."""
        if key in self._entries:
            self._entries.move_to_end(key)
            return
        self._entries[key] = (obj, nbytes)
        self._bytes += nbytes
        if self._bytes <= self.host_budget_bytes:
            return
        low = int(self.low_watermark * self.host_budget_bytes)
        while self._bytes > low and len(self._entries) > 1:
            old_key, (_, old_bytes) = self._entries.popitem(last=False)
            self._bytes -= old_bytes
            self._evictions += 1

    def release(self, keys: Sequence[str]) -> None:
        """Drop consumed entries from tier 1 (cheaper than waiting for the
        watermark to push them out; counted separately from evictions)."""
        with self._lock:
            for key in keys:
                ent = self._entries.pop(key, None)
                if ent is not None:
                    self._bytes -= ent[1]
                    self._released += 1

    def put(self, key: str, obj: Any, *, admit: bool = False) -> int:
        """Serialize ``obj`` and write it through to the backend.  Returns
        the payload size.  ``admit=True`` also caches it in tier 1 (off by
        default so a build/spill pass doesn't flush the read cache)."""
        data = self._dumps(obj)
        self.backend.put(key, data)
        if admit:
            with self._lock:
                self._admit(key, obj, len(data))
        return len(data)

    def resident(self, key: str) -> bool:
        with self._lock:
            return key in self._entries

    # ------------------------------------------------ the overlap loop

    def stream_windows(self, keys: Sequence[str], *, window: int,
                       lookahead: int = 1,
                       release: bool = True) -> Iterator[List[Any]]:
        """Yield ``keys`` in windows of ``window`` objects, overlapping the
        NEXT ``lookahead`` windows' backend I/O with the consumer's work on
        the current one:

            prime:   prefetch windows 0..lookahead-1
            yield i: window i's objects (hits — their fetches were issued
                     one iteration ago), after scheduling window
                     i+lookahead's prefetch; that prefetch streams in
                     while the consumer works on the yielded window
            resume:  release window i's entries (the consumer is done with
                     them — the generator only resumes when it asks for
                     window i+1), keeping resident bytes ~(1 + lookahead)
                     windows

        Window i's ``get``s run BEFORE window i+lookahead's prefetch is
        scheduled, so a budget too small for (1+lookahead) windows never
        double-fetches: the yielded objects hold their own references and
        survive any cache eviction the lookahead's admits cause.  Each key
        is fetched exactly once as long as the budget fits the pipeline's
        resident set — (1 + ``lookahead``) windows' payload bytes (below
        that, admits can evict prefetched-but-unconsumed entries — a
        refetch, never an error).  ``lookahead=0`` disables the overlap (each window's I/O is
        paid synchronously inside its ``get``s) — the serial baseline the
        store benchmark compares against.  Nothing beyond window
        ``i + lookahead`` is ever touched, so decode of window i never
        waits on window i+2's I/O (with the default lookahead).
        """
        if window < 1:
            raise ValueError("window must be >= 1")
        wins = [list(keys[i:i + window])
                for i in range(0, len(keys), window)]
        for w in wins[:max(0, lookahead)]:
            self.prefetch(w)
        for i, w in enumerate(wins):
            objs = [self.get(k) for k in w]
            nxt = i + max(0, lookahead)
            if lookahead and nxt < len(wins):
                self.prefetch(wins[nxt])
            yield objs
            if release:
                self.release(w)

    # ----------------------------------------------------------- lifecycle

    def stats(self) -> StoreStats:
        with self._lock:
            snap = dict(
                host_hits=self._hits, host_misses=self._misses,
                host_evictions=self._evictions,
                host_released=self._released,
                host_bytes=self._bytes, host_entries=len(self._entries),
                backend_fetches=self._fetches,
                backend_bytes_fetched=self._fetched_bytes,
                inflight_fetches=len(self._inflight),
                bytes_in_flight=sum(self._inflight_bytes.values()))
        if self._tier0 is not None:
            s = self._tier0.stats()
            snap.update(decoded_hits=s.cache_hits,
                        decoded_misses=s.cache_misses,
                        decoded_bytes=s.cache_bytes)
        return StoreStats(**snap)

    def close(self, wait: bool = True) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._pool.shutdown(wait=wait)

    def __enter__(self) -> "TieredBlobStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def filesystem_store(root, *, host_budget_bytes: int = 256 << 20,
                     read_delay_s: float = 0.0,
                     **kw) -> TieredBlobStore:
    """Convenience: a :class:`TieredBlobStore` over a directory — e.g. the
    checkpoint dir, so ``restore(store=filesystem_store(ckpt_dir, ...))``
    demand-pages ``step_N/<leaf>.blob`` files window by window."""
    return TieredBlobStore(FilesystemBackend(root, read_delay_s=read_delay_s),
                           host_budget_bytes=host_budget_bytes, **kw)
