"""Per-(codec, width, device_kind) kernel autotuning + persistent jit cache.

CODAG's throughput argument is that decompression must saturate the
hardware scheduler — but every knob that controls saturation in this repo
was a hand-picked constant: ``format.DEFAULT_CHUNK_BYTES`` (how many
elements each independent stream carries), the pow2 bucketing column floor
in ``format.pad_table_to_bucket`` (jit-cache reuse vs padding waste), the
generic Pallas wrapper's pipeline depth, and bitpack's output tile.
Sitaridi et al. (arXiv 1606.00519) and Rivera et al. (arXiv 2201.09118)
both show the winning configuration shifts per format and per device; this
module makes those knobs *data*:

  * a committed tuned-defaults table (``tuned_defaults.json`` next to this
    module) keyed ``codec -> w<width> -> device_kind -> {knob: value}``.
    ``DecodePlan.build``/``pad_table_to_bucket`` (bucket floor),
    ``api.compress``/``encoders.compress`` (chunk geometry), and
    ``plan.dispatch`` (kernel knobs) consult it automatically whenever the
    caller did not pass the knob explicitly — explicit kwargs always win,
    and an unknown device_kind falls back to the hand-picked constants.
  * :func:`autotune` — the offline search that regenerates the table from
    each codec's registry ``demo_data`` on the current device.
  * :func:`enable_compile_cache` — the ONE entry point that wires jax's
    persistent compilation cache (replica cold start was paying ~3.3 s of
    recompilation per process vs a ~5 ms steady-state dispatch; the cache
    turns the second process's compile into a disk load).  Used by the
    service (``DecompressionService(compile_cache=...)``), the benchmark
    driver (``benchmarks.run --compile-cache``), and the launch scripts.

Knob vocabulary (see KNOWN_KNOBS):

  chunk_bytes       encode-time: uncompressed bytes per chunk (= per
                    independent decode stream).
  bucket_cols_floor serving-time: minimum pow2 column bucket for fused
                    window tables.
  num_stages        decode-time: rows per Pallas grid cell in the generic
                    wrapper — the pipeline's DMA blocking depth (the
                    HBM->VMEM load of block i+1 double-buffers against the
                    decode of block i; deeper blocks amortize DMA latency).
  <codec tunables>  decode-time knobs a codec declares on its DecodeSpec
                    (``harness.Tunable``), e.g. bitpack's output ``tile``.

Keys starting with ``_`` are provenance (measured throughputs, autotune
config), never knobs.
"""
from __future__ import annotations

import contextlib
import functools
import json
import os
import threading
import time
import warnings
from pathlib import Path
from typing import Any, Dict, Iterable, Optional, Sequence, Tuple

# The committed table (shipped as package data next to this module).
DEFAULT_TABLE_PATH = Path(__file__).with_name("tuned_defaults.json")

TABLE_VERSION = 1

# Knobs the framework itself owns; codecs extend the vocabulary via
# ``DecodeSpec.tunables``.  chunk_bytes/bucket_cols_floor are resolved on
# the host paths; everything else is a kernel knob threaded to the decode
# dispatch as a static ``tune`` tuple.
KNOWN_KNOBS = ("chunk_bytes", "bucket_cols_floor", "num_stages")
_HOST_KNOBS = frozenset(("chunk_bytes", "bucket_cols_floor"))

# Default persistent-cache location; override with the env var or an
# explicit path argument.
CACHE_DIR_ENV = "REPRO_COMPILE_CACHE_DIR"
DEFAULT_CACHE_DIR = Path.home() / ".cache" / "repro-codag-jax"

_lock = threading.Lock()
_table: Optional[Dict[str, Any]] = None          # loaded (or injected) table
_table_path: Optional[Path] = None
_cache_enabled_at: Optional[Path] = None


# --------------------------------------------------------------------------
# device identity
# --------------------------------------------------------------------------


def normalize_kind(kind: str) -> str:
    """Normalize a jax ``device_kind`` string to a table key slug."""
    return "-".join(str(kind).strip().lower().split())


@functools.lru_cache(maxsize=1)
def device_kind() -> str:
    """The normalized device kind of the default jax device (e.g. ``cpu``,
    ``tpu-v4``).  Cached — the backend does not change within a process."""
    import jax
    return normalize_kind(jax.devices()[0].device_kind)


# --------------------------------------------------------------------------
# table load / lookup
# --------------------------------------------------------------------------


def empty_table() -> Dict[str, Any]:
    return {"version": TABLE_VERSION, "codecs": {}}


def load_table(path: Optional[Path] = None) -> Dict[str, Any]:
    """Load a tuned-defaults table from disk (missing file -> empty table)."""
    p = Path(path) if path is not None else DEFAULT_TABLE_PATH
    if not p.exists():
        return empty_table()
    table = json.loads(p.read_text())
    if table.get("version") != TABLE_VERSION:
        raise ValueError(
            f"tuned-defaults table {p} has version {table.get('version')!r}, "
            f"expected {TABLE_VERSION}")
    return table


def save_table(table: Dict[str, Any], path: Optional[Path] = None) -> Path:
    """Write a table in the canonical committed form (sorted, 2-indent)."""
    p = Path(path) if path is not None else DEFAULT_TABLE_PATH
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(table, indent=2, sort_keys=True) + "\n")
    return p


def _current_table() -> Dict[str, Any]:
    global _table
    with _lock:
        if _table is None:
            _table = load_table(_table_path)
        return _table


def set_table(table: Optional[Dict[str, Any]],
              path: Optional[Path] = None) -> None:
    """Install ``table`` as the active tuned defaults (None -> reload from
    ``path`` / the committed file lazily).  Clears the lookup caches."""
    global _table, _table_path
    with _lock:
        _table = table
        _table_path = Path(path) if path is not None else None
    lookup.cache_clear()
    kernel_tune.cache_clear()


@contextlib.contextmanager
def override(table: Optional[Dict[str, Any]]):
    """Temporarily install a tuned-defaults table (tests; None = no table)."""
    global _table, _table_path
    with _lock:
        prev, prev_path = _table, _table_path
    set_table(table if table is not None else empty_table())
    try:
        yield
    finally:
        with _lock:
            _table, _table_path = prev, prev_path
        lookup.cache_clear()
        kernel_tune.cache_clear()


@functools.lru_cache(maxsize=None)
def lookup(codec: str, width: int, kind: Optional[str] = None) -> dict:
    """Tuned knobs for ``(codec, width, device_kind)``.

    Returns ``{}`` — fall back to the hand-picked constants — whenever any
    level of the table is missing: unknown codec, an explicit per-codec
    fallback (an empty codec section), unknown width, or an unknown/never-
    tuned device kind.  Provenance keys (``_``-prefixed) are stripped.
    """
    kind = kind if kind is not None else device_kind()
    entry = (_current_table().get("codecs", {})
             .get(codec, {})
             .get(f"w{int(width)}", {})
             .get(normalize_kind(kind), {}))
    return {k: v for k, v in entry.items() if not k.startswith("_")}


def chunk_bytes_for(codec: str, width: int,
                    kind: Optional[str] = None) -> Optional[int]:
    """Tuned encode chunk size, or None (caller uses DEFAULT_CHUNK_BYTES)."""
    v = lookup(codec, width, kind).get("chunk_bytes")
    return int(v) if v is not None else None


def encode_width(codec_name: str, dtype) -> int:
    """The blob width a codec produces for arrays of ``dtype`` (the table's
    width key): byte-stream codecs always emit width-1 blobs; 8-byte dtypes
    are viewed/plane-decomposed to 4."""
    import numpy as np

    from repro.core import registry
    if registry.get(codec_name).byte_stream:
        return 1
    w = np.dtype(dtype).itemsize
    return 4 if w == 8 else w


def bucket_cols_floor(codec: str, width: int,
                      kind: Optional[str] = None) -> Optional[int]:
    """Tuned pow2-bucketing column floor, or None (caller uses 128)."""
    v = lookup(codec, width, kind).get("bucket_cols_floor")
    return int(v) if v is not None else None


@functools.lru_cache(maxsize=None)
def kernel_tune(codec: str, width: int,
                explicit: Tuple[Tuple[str, Any], ...] = ()) -> tuple:
    """The static ``tune`` tuple for one decode dispatch.

    Table-tuned kernel knobs (everything in the entry that is not a host
    knob) merged with ``explicit`` overrides (``EngineConfig.tune`` /
    direct ``ops.decode(tune=)`` callers) — explicit wins per knob.  The
    result is a sorted, hashable ``((name, value), ...)`` tuple, safe as a
    jit static argument.
    """
    merged = {k: v for k, v in lookup(codec, width).items()
              if k not in _HOST_KNOBS}
    merged.update(dict(explicit))
    return tuple(sorted(merged.items()))


# --------------------------------------------------------------------------
# persistent compilation cache
# --------------------------------------------------------------------------


def enable_compile_cache(path: Optional[os.PathLike] = None) -> Path:
    """Point jax's persistent compilation cache at ``path`` (default: the
    ``REPRO_COMPILE_CACHE_DIR`` env var, else ``~/.cache/repro-codag-jax``).

    This is the single entry point every long-lived consumer uses — the
    serving front end, the benchmark driver, and the launch scripts — so a
    replica's second process loads its decode kernels from disk instead of
    re-paying XLA compilation (the serving bench's ~3.3 s cold start).
    The thresholds are dropped to zero so even the small per-bucket decode
    computations are cached.  Idempotent; returns the cache directory.
    """
    global _cache_enabled_at
    import jax

    p = Path(path if path is not None
             else os.environ.get(CACHE_DIR_ENV, DEFAULT_CACHE_DIR))
    p.mkdir(parents=True, exist_ok=True)
    with _lock:
        if _cache_enabled_at == p:
            return p
        jax.config.update("jax_compilation_cache_dir", str(p))
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        try:  # cache XLA-internal autotuning artifacts too, where supported
            jax.config.update("jax_persistent_cache_enable_xla_caches", "all")
        except AttributeError as e:  # older jax: flag does not exist
            warnings.warn(
                f"persistent compile cache: jax too old to cache "
                f"XLA-internal artifacts ({e}); compiled decode kernels "
                f"are still cached", RuntimeWarning, stacklevel=2)
        # jax initializes the persistent cache lazily at the FIRST compile
        # and never re-reads the config after that, so enabling it in a
        # process that already jitted something would silently do nothing.
        # Dropping the in-memory handle (disk is untouched) forces the next
        # compile to re-initialize against the directory set above.
        try:
            from jax.experimental.compilation_cache import (
                compilation_cache as _cc)
            _cc.reset_cache()
        except Exception as e:  # cache module moved/renamed
            warnings.warn(
                f"persistent compile cache at {p} could not be "
                f"re-initialized ({type(e).__name__}: {e}); computations "
                f"already jitted in this process may not be persisted",
                RuntimeWarning, stacklevel=2)
        _cache_enabled_at = p
    return p


def compile_cache_dir() -> Optional[Path]:
    """The directory :func:`enable_compile_cache` installed, or None."""
    with _lock:
        return _cache_enabled_at


# --------------------------------------------------------------------------
# the offline autotuner
# --------------------------------------------------------------------------

# Candidate chunk sizes (uncompressed bytes per stream).  The hand-picked
# default (format.DEFAULT_CHUNK_BYTES) is always appended so "tuned" can
# never measure worse than it on the tuning workload except by noise.
SMOKE_CHUNK_BYTES = (4 * 1024, 16 * 1024, 64 * 1024)
FULL_CHUNK_BYTES = (4 * 1024, 16 * 1024, 64 * 1024, 256 * 1024)
NUM_STAGES_CANDIDATES = (1, 2, 4)


def _median_time(fn, iters: int, warmup: int = 1) -> float:
    import jax
    import numpy as np
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _measure(blob, engine, tune: Tuple[Tuple[str, Any], ...],
             iters: int) -> float:
    """Decoded (uncompressed) MB/s of one blob under one knob point."""
    from repro.core import plan as plan_mod
    plan = plan_mod.DecodePlan.build([blob])
    cfg = engine.config
    if tune:
        import dataclasses
        cfg = dataclasses.replace(
            cfg, tune=tuple(sorted({**dict(cfg.tune), **dict(tune)}.items())))
        import repro.core.engine as engine_mod
        engine = engine_mod.CodagEngine(cfg)
    t = _median_time(lambda: plan.execute_device(engine), iters=iters)
    return blob.uncompressed_bytes / max(t, 1e-9) / 1e6


def _kernel_knob_space(codec, engine) -> Iterable[Tuple[Tuple[str, Any], ...]]:
    """Kernel-knob grid for one codec: the generic wrapper's ``num_stages``
    plus the codec's declared ``DecodeSpec.tunables``.  Searched only when
    the engine runs real (non-interpret) Pallas — on the XLA/interpret
    paths these knobs are no-ops and searching them would only fit noise."""
    import itertools
    if engine.config.backend != "pallas" or engine.config.interpret:
        yield ()
        return
    axes = []
    if codec.decode.pallas_override is None:
        axes.append([("num_stages", s) for s in NUM_STAGES_CANDIDATES])
    for t in getattr(codec.decode, "tunables", ()):
        axes.append([(t.name, c) for c in t.candidates])
    if not axes:
        yield ()
        return
    for combo in itertools.product(*axes):
        yield tuple(combo)


def autotune(codecs: Optional[Sequence[str]] = None, *,
             size_mb: float = 0.25, smoke: bool = False,
             engine=None, iters: int = 3, seed: int = 0,
             chunk_bytes_candidates: Optional[Sequence[int]] = None,
             ) -> Tuple[Dict[str, Any], list]:
    """Search the knob space per codec on the current device.

    Returns ``(table, rows)``: a tuned-defaults table for THIS device kind
    (merge/save with :func:`save_table`) and bench-style
    ``(name, value, derived)`` rows (tuned vs hand-picked throughput per
    codec — the ``BENCH_autotune.json`` payload).
    """
    import numpy as np

    from repro.core import api, format as fmt, registry
    from repro.core.engine import CodagEngine, EngineConfig

    engine = engine or CodagEngine(EngineConfig())
    kind = device_kind()
    if smoke:
        size_mb = min(size_mb, 0.05)
    cands = tuple(chunk_bytes_candidates
                  or (SMOKE_CHUNK_BYTES if smoke else FULL_CHUNK_BYTES))
    if fmt.DEFAULT_CHUNK_BYTES not in cands:
        cands = cands + (fmt.DEFAULT_CHUNK_BYTES,)

    table = empty_table()
    rows: list = []
    rng = np.random.default_rng(seed)
    names = list(codecs) if codecs else list(registry.names())
    for name in names:
        codec = registry.get(name)
        if codec.demo_data is None:
            continue
        n_elems = max(1024, int(size_mb * (1 << 20))
                      // (1 if codec.byte_stream else 4))
        arr = codec.demo_data(n_elems, rng)
        width = encode_width(name, arr.dtype)

        best: Dict[str, Any] = {}
        best_mbps = 0.0
        default_mbps = 0.0
        # the search is explicit-knob only: tuned defaults must not leak
        # into their own baseline measurement
        with override(empty_table()):
            for cb in cands:
                blob = api.compress(arr, name, chunk_bytes=cb).blobs[0]
                for ktune in _kernel_knob_space(codec, engine):
                    mbps = _measure(blob, engine, ktune, iters)
                    if cb == fmt.DEFAULT_CHUNK_BYTES and not ktune:
                        default_mbps = mbps
                    if mbps > best_mbps:
                        best_mbps = mbps
                        best = {"chunk_bytes": int(cb), **dict(ktune)}
        entry = dict(best)
        entry["_tuned_MBps"] = round(best_mbps, 3)
        entry["_default_MBps"] = round(default_mbps, 3)
        entry["_size_mb"] = size_mb
        table["codecs"].setdefault(name, {})[f"w{width}"] = {kind: entry}
        speedup = best_mbps / max(default_mbps, 1e-9)
        rows += [
            (f"autotune/{name}/tuned_MBps", round(best_mbps, 3),
             f"knobs={best}"),
            (f"autotune/{name}/default_MBps", round(default_mbps, 3),
             f"chunk_bytes={fmt.DEFAULT_CHUNK_BYTES}"),
            (f"autotune/{name}/speedup", round(speedup, 3),
             "tuned vs hand-picked"),
            (f"autotune/{name}/chunk_bytes", int(best.get(
                "chunk_bytes", fmt.DEFAULT_CHUNK_BYTES)), ""),
        ]
    n_better = sum(1 for n, v, _ in rows
                   if n.endswith("/speedup") and v > 1.0)
    rows.append(("autotune/codecs_improved", n_better,
                 "codecs where tuned beats hand-picked"))
    return table, rows


def merge_tables(base: Dict[str, Any], new: Dict[str, Any]) -> Dict[str, Any]:
    """Merge ``new`` entries into ``base`` at (codec, width, kind)
    granularity — an autotune run on one device never clobbers another
    device's committed entries."""
    out = {"version": TABLE_VERSION,
           "codecs": {c: {w: dict(kinds) for w, kinds in ws.items()}
                      for c, ws in base.get("codecs", {}).items()}}
    for c, ws in new.get("codecs", {}).items():
        for w, kinds in ws.items():
            out["codecs"].setdefault(c, {}).setdefault(w, {}).update(kinds)
    return out
