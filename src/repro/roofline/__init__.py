# Roofline analysis from compiled dry-run artifacts (no real hardware).
