"""Roofline terms from compiled artifacts (TPU v5e targets, CPU dry-run).

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bw_per_chip
    collective = collective_bytes_per_device / ICI_link_bw

HLO FLOPs / bytes come from ``compiled.cost_analysis()`` (per-partition
numbers — verified empirically: a (2,4)-sharded matmul reports 1/8 of the
global FLOPs).  Collective bytes are parsed from the optimized HLO text:
every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute instruction contributes its *result* shape bytes (the
``-start`` form counted once, ``-done`` skipped).  That is a per-device,
per-invocation proxy for link traffic; ring-algorithm factors (2(n-1)/n for
all-reduce etc.) are folded in as noted in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12       # bf16 FLOP/s
HBM_BW = 819e9            # bytes/s
ICI_BW = 50e9             # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"\b([a-z]+\d*)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective op kind from optimized HLO."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.*)$", stripped)
        if not m:
            continue
        rest = m.group(1)
        op = None
        for cand in _COLL_OPS:
            if re.search(rf"\b{cand}(-start)?\(", rest):
                op = cand
                break
        if op is None or f"{op}-done" in rest:
            continue
        # result shapes = everything before the op token
        head = rest.split(f" {op}")[0]
        nbytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(head))
        if f"{op}-start" in rest:
            nbytes //= 2  # start-op tuples alias (operand, result)
        out[op] = out.get(op, 0) + nbytes
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                  # per device
    hbm_bytes: float              # per device
    coll_bytes: float             # per device
    coll_by_op: Dict[str, int]
    model_flops: float            # global useful FLOPs (6ND / 2ND)
    n_chips: int

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / (HLO flops summed over chips)."""
        total = self.flops * self.n_chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Upper bound on achievable MFU given the dominant term."""
        if self.t_bound == 0:
            return 0.0
        return (self.model_flops / self.n_chips / PEAK_FLOPS) / self.t_bound

    def to_dict(self) -> dict:
        return {
            "flops_per_dev": self.flops,
            "hbm_bytes_per_dev": self.hbm_bytes,
            "coll_bytes_per_dev": self.coll_bytes,
            "coll_by_op": self.coll_by_op,
            "model_flops": self.model_flops,
            "n_chips": self.n_chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_ratio,
            "mfu_bound": self.mfu_bound,
        }


def analyze(compiled, model_flops: float, n_chips: int,
            hlo_text: Optional[str] = None) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    txt = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(txt)
    return Roofline(
        flops=float(ca.get("flops", 0.0)),
        hbm_bytes=float(ca.get("bytes accessed", 0.0)),
        coll_bytes=float(sum(coll.values())),
        coll_by_op=coll,
        model_flops=model_flops,
        n_chips=n_chips,
    )


def model_flops_for(cfg, shape) -> float:
    """6·N_active·tokens for train, 2·N_active·tokens for inference."""
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens
