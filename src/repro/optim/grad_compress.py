"""Gradient compression for cross-pod collectives.

The paper's thesis — decompression throughput is worth engineering for —
applied to the collective plane.  Inter-pod links (DCI) are an order of
magnitude slower than intra-pod ICI, so the bytes crossing them are the
scarce resource.  Three tools:

1. ``quantize_leaf`` / ``dequantize_leaf``: the int8 per-block-128 grid
   every wire format in this repo shares (one quantization block == one
   bitpack wire chunk, so per-block scales broadcast in decode epilogues).
2. ``quantize_grads``: stateless quantize->dequantize pass used as the
   `grad_compressor` hook in build_train_step — numerically faithful to an
   int8 wire (values pass through the int8 grid) without moving bytes.
3. ``topk_select`` / ``topk_sparsify`` + error feedback: keep EXACTLY the
   top-k entries by magnitude (ties broken deterministically by index),
   accumulate the residual locally (momentum-correct SGD-EF).

The collectives that actually move these formats live in
``distributed/collectives.py``: the registry-codec wire encode, the
all-gather of compressed bytes + chunk tables, and the receive path
lowered through ``DecodePlan`` with fused dequant→reduce epilogues.  The
seed-era ``compressed_psum`` here (plain int8 all-gather outside the plan
IR) is kept as the reference implementation the compressed wire is tested
against.  DiLoCo outer sync (distributed/diloco.py) composes the
collective across the 'pod' axis every H inner steps.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

QBLOCK = 128


def quantize_leaf(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % QBLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_leaf(q: jnp.ndarray, scale: jnp.ndarray, shape,
                    dtype=jnp.float32) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def quantize_grads(grads):
    """Stateless int8 wire-format pass (grad_compressor hook)."""
    def qdq(g):
        if g.size < QBLOCK:
            return g
        q, s = quantize_leaf(g)
        return dequantize_leaf(q, s, g.shape, g.dtype)
    return jax.tree.map(qdq, grads)


def compressed_psum(x: jnp.ndarray, axis_name: str):
    """int8 all-gather + local dequant-sum; call INSIDE shard_map."""
    q, s = quantize_leaf(x)
    qg = jax.lax.all_gather(q, axis_name)          # (n, nb, B) int8 on wire
    sg = jax.lax.all_gather(s, axis_name)
    deq = qg.astype(jnp.float32) * sg              # (n, nb, B)
    summed = jnp.sum(deq, axis=0)
    n = x.size
    return summed.reshape(-1)[:n].reshape(x.shape)


def make_compressed_psum_fn(mesh, axis: str = "pod"):
    """Jit-able tree-wise compressed all-reduce over one mesh axis.

    Input tree leaves carry a leading per-member axis of size
    mesh.shape[axis] (e.g. per-pod parameter replicas in the DiLoCo outer
    loop); each member contributes its slice, receives the int8-wire sum.
    """

    def tree_psum(tree):
        flat, tdef = jax.tree.flatten(tree)

        def body(*leaves):
            # leaves arrive with the leading member axis reduced to 1
            return tuple(
                compressed_psum(l[0], axis)[None] for l in leaves)

        specs = tuple(P(axis) for _ in flat)
        out = shard_map(body, mesh=mesh, in_specs=specs, out_specs=specs,
                        check_rep=False)(*flat)
        return tdef.unflatten(list(out))

    return tree_psum


def wire_bytes_f32_allreduce(nbytes: int, n: int) -> float:
    """Ring all-reduce wire bytes per member for an f32 payload."""
    return 2.0 * nbytes * (n - 1) / n


def wire_bytes_compressed(nbytes: int, n: int) -> float:
    """int8 all-gather wire bytes per member (values/4 + scales/128)."""
    payload = nbytes / 4.0 + (nbytes / 4.0 / QBLOCK) * 4.0
    return payload * (n - 1)


# ---------------------------------------------------------------------------
# top-k sparsification with error feedback
# ---------------------------------------------------------------------------


def topk_select(flat: jnp.ndarray, k: int):
    """Exactly-k magnitude selection over a flat vector.

    Returns ``(mask, kept)`` where ``mask`` is boolean with EXACTLY k True
    entries and ``kept = where(mask, flat, 0)``.  Ties are broken
    deterministically by index (``lax.top_k`` is stable: equal magnitudes
    keep the lower index), so the wire-bytes estimate ``topk_wire_bytes``
    is exact even on tied inputs — e.g. already-quantized grads, where a
    threshold test (``abs >= thresh``) can keep far more than k."""
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    mask = jnp.zeros(flat.shape, bool).at[idx].set(True)
    return mask, jnp.where(mask, flat, 0.0)


def topk_sparsify(g: jnp.ndarray, residual: jnp.ndarray, frac: float = 0.01):
    """Keep exactly the top-`frac` entries of (g + residual) by magnitude.

    Returns (sparse_g, new_residual).  The surviving values + a bitpacked
    index mask are what crosses the wire (mask = 1 bit/elem via the
    paper's bitpack codec; values = 32/16-bit each) — see
    ``distributed.collectives.topk_psum`` for the actual collective."""
    acc = g.astype(jnp.float32) + residual
    k = max(1, int(acc.size * frac))
    flat = acc.reshape(-1)
    mask, kept = topk_select(flat, k)
    new_residual = (flat - kept).reshape(acc.shape)
    return kept.reshape(acc.shape).astype(g.dtype), new_residual


def topk_wire_bytes(size: int, frac: float) -> float:
    """values (f16) + 1-bit bitpacked mask, per member.

    Exact: ``topk_select`` guarantees the mask carries exactly
    ``max(1, int(size*frac))`` set bits."""
    k = max(1, int(size * frac))
    return k * 2.0 + size / 8.0
