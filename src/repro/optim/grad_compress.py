"""Gradient compression for cross-pod collectives.

The paper's thesis — decompression throughput is worth engineering for —
applied to the collective plane.  Inter-pod links (DCI) are an order of
magnitude slower than intra-pod ICI, so the bytes crossing them are the
scarce resource.  Three tools:

1. ``quantize_grads`` / stateless int8 wire format: per-block-128 scales,
   quantize -> dequantize around the (GSPMD-inserted) all-reduce.  Used as
   the `grad_compressor` hook in build_train_step; numerically faithful to
   an int8 wire (values pass through the int8 grid), 4x fewer wire bytes
   when the runtime collective is int8 (shard_map path below).
2. ``compressed_psum`` (shard_map): an *actual* int8 collective — each
   member quantizes, all-gathers int8+scales over the axis, dequantizes and
   sums locally.  Wire bytes: n*B/4 vs f32 ring all-reduce's ~2B.
3. ``topk_sparsify`` + error feedback: keep the top-k fraction by
   magnitude, accumulate the residual locally (momentum-correct SGD-EF),
   bitpack the index bitmap with the paper's bitpack codec for the wire.

DiLoCo-style outer sync (distributed/diloco.py) composes (2) across the
'pod' axis every H inner steps.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

QBLOCK = 128


def quantize_leaf(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % QBLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_leaf(q: jnp.ndarray, scale: jnp.ndarray, shape,
                    dtype=jnp.float32) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def quantize_grads(grads):
    """Stateless int8 wire-format pass (grad_compressor hook)."""
    def qdq(g):
        if g.size < QBLOCK:
            return g
        q, s = quantize_leaf(g)
        return dequantize_leaf(q, s, g.shape, g.dtype)
    return jax.tree.map(qdq, grads)


def compressed_psum(x: jnp.ndarray, axis_name: str):
    """int8 all-gather + local dequant-sum; call INSIDE shard_map."""
    q, s = quantize_leaf(x)
    qg = jax.lax.all_gather(q, axis_name)          # (n, nb, B) int8 on wire
    sg = jax.lax.all_gather(s, axis_name)
    deq = qg.astype(jnp.float32) * sg              # (n, nb, B)
    summed = jnp.sum(deq, axis=0)
    n = x.size
    return summed.reshape(-1)[:n].reshape(x.shape)


def make_compressed_psum_fn(mesh, axis: str = "pod"):
    """Jit-able tree-wise compressed all-reduce over one mesh axis.

    Input tree leaves carry a leading per-member axis of size
    mesh.shape[axis] (e.g. per-pod parameter replicas in the DiLoCo outer
    loop); each member contributes its slice, receives the int8-wire sum.
    """

    def tree_psum(tree):
        flat, tdef = jax.tree.flatten(tree)

        def body(*leaves):
            # leaves arrive with the leading member axis reduced to 1
            return tuple(
                compressed_psum(l[0], axis)[None] for l in leaves)

        specs = tuple(P(axis) for _ in flat)
        out = shard_map(body, mesh=mesh, in_specs=specs, out_specs=specs,
                        check_rep=False)(*flat)
        return tdef.unflatten(list(out))

    return tree_psum


def wire_bytes_f32_allreduce(nbytes: int, n: int) -> float:
    """Ring all-reduce wire bytes per member for an f32 payload."""
    return 2.0 * nbytes * (n - 1) / n


def wire_bytes_compressed(nbytes: int, n: int) -> float:
    """int8 all-gather wire bytes per member (values/4 + scales/128)."""
    payload = nbytes / 4.0 + (nbytes / 4.0 / QBLOCK) * 4.0
    return payload * (n - 1)


# ---------------------------------------------------------------------------
# top-k sparsification with error feedback
# ---------------------------------------------------------------------------


def topk_sparsify(g: jnp.ndarray, residual: jnp.ndarray, frac: float = 0.01):
    """Keep top-`frac` entries of (g + residual) by magnitude.

    Returns (sparse_g, new_residual).  The surviving values + a bitpacked
    index mask are what crosses the wire (mask = 1 bit/elem via the
    paper's bitpack codec; values = 32/16-bit each)."""
    acc = g.astype(jnp.float32) + residual
    k = max(1, int(acc.size * frac))
    flat = acc.reshape(-1)
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(flat) >= thresh
    kept = jnp.where(mask, flat, 0.0)
    new_residual = (flat - kept).reshape(acc.shape)
    return kept.reshape(acc.shape).astype(g.dtype), new_residual


def topk_wire_bytes(size: int, frac: float) -> float:
    """values (f16) + 1-bit bitpacked mask, per member."""
    k = max(1, int(size * frac))
    return k * 2.0 + size / 8.0
