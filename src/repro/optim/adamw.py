"""AdamW with optional int8 block-quantized moments.

Moment compression is the paper's decompression technique applied to
optimizer state: moments are stored as int8 with per-block (128) fp32
scales and "decompressed" (dequantized) on use — 4x HBM saving on m/v,
which is what makes the 1T-param config's optimizer state approachable
(EXPERIMENTS.md §Dry-run).  ZeRO-1 sharding of the state over the 'data'
axis is applied by the launch layer via `sharding.zero1_specs`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

QBLOCK = 128


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    compress_moments: bool = False   # int8 + per-block scale


def _quantize(x: jnp.ndarray, sqrt_domain: bool = False):
    """int8 block quantization; the second moment is quantized in the
    sqrt domain (v spans ~8 orders of magnitude near convergence — linear
    int8 there destroys the effective lr; sqrt halves the dynamic range)."""
    flat = x.reshape(-1)
    if sqrt_domain:
        flat = jnp.sqrt(jnp.maximum(flat, 0.0))
    pad = (-flat.shape[0]) % QBLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray, shape,
                sqrt_domain: bool = False) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if sqrt_domain:
        flat = jnp.square(flat)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def init(params, cfg: AdamWConfig) -> Dict[str, Any]:
    def zeros_like_moment(p):
        if cfg.compress_moments:
            q, s = _quantize(jnp.zeros(p.shape, jnp.float32))
            return {"q": q, "s": s}
        return jnp.zeros(p.shape, jnp.float32)

    return {
        "step": jnp.int32(0),
        "m": jax.tree.map(zeros_like_moment, params),
        "v": jax.tree.map(zeros_like_moment, params),   # sqrt-domain int8
    }


def apply(params, grads, state, cfg: AdamWConfig):
    step = state["step"] + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        if cfg.compress_moments:
            m_f = _dequantize(m["q"], m["s"], p.shape)
            v_f = _dequantize(v["q"], v["s"], p.shape, sqrt_domain=True)
        else:
            m_f, v_f = m, v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * jnp.square(g)
        mh = m_f / b1c
        vh = v_f / b2c
        p32 = p.astype(jnp.float32)
        p32 = p32 - cfg.lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                              + cfg.weight_decay * p32)
        if cfg.compress_moments:
            qm, sm = _quantize(m_f)
            qv, sv = _quantize(v_f, sqrt_domain=True)
            return p32.astype(p.dtype), {"q": qm, "s": sm}, {"q": qv, "s": sv}
        return p32.astype(p.dtype), m_f, v_f

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"step": step, "m": new_m, "v": new_v}
