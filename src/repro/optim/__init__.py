# Optimizer + gradient/optimizer-state compression (the paper's codecs
# applied to the training data plane).
