"""RLE v2 decode — Pallas TPU kernel (run / delta / literal / long-run).

Same two-phase architecture as rle_v1.py; the only change a codec author
makes is the Phase-1 header parse and the Phase-2 value expression — this is
the modularity the paper's framework claims (§IV-A): reading, group-table
management, and expansion machinery are untouched.

Phase-2 expansion implements Table II `write_run(init, len, delta)` for all
lanes at once: out[i] = base[g] + delta[g] * (i - start[g]) in wraparound
uint32 arithmetic (delta == 0 for plain runs), literals gathered from the
compressed bytes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core import streams as st
from repro.kernels.ref import DEV_DTYPE


def max_groups(out_len: int) -> int:
    return out_len // 2 + 4


def decode_chunk(comp: jnp.ndarray, out_len_dyn, out_len_max: int,
                 width: int) -> jnp.ndarray:
    MG = max_groups(out_len_max)
    dt = DEV_DTYPE[width]

    # ---- Phase 1: sequential header parse --------------------------------
    def cond(s):
        return jnp.logical_and(s[2] < out_len_dyn, s[1] < MG)

    def body(s):
        pos, g, cnt, starts, kinds, bases, deltas, litoff = s
        h = st.read_byte_at(comp, pos)
        mode = h >> 6
        f = h & 63
        nxt = st.read_byte_at(comp, pos + 1)
        is_lit = mode == 2
        is_delta = mode == 1
        is_long = mode == 3
        length = jnp.where(is_lit, f + 1,
                  jnp.where(is_long, ((f << 8) | nxt) + 3, f + 3))
        val_off = pos + 1 + jnp.where(is_long, 1, 0)
        base = st.read_value_at(comp, val_off, width)
        delta = jnp.where(is_delta,
                          st.read_value_at(comp, val_off + width, width),
                          jnp.uint32(0))
        starts = starts.at[g].set(cnt)
        kinds = kinds.at[g].set(is_lit)
        bases = bases.at[g].set(base)
        deltas = deltas.at[g].set(delta)
        litoff = litoff.at[g].set(pos + 1)
        adv = jnp.where(is_lit, 1 + length * width,
               jnp.where(is_delta, 1 + 2 * width,
                jnp.where(is_long, 2 + width, 1 + width)))
        return (pos + adv, g + 1, cnt + length,
                starts, kinds, bases, deltas, litoff)

    init = (jnp.int32(0), jnp.int32(0), jnp.int32(0),
            jnp.full((MG,), out_len_max, jnp.int32),
            jnp.zeros((MG,), jnp.bool_),
            jnp.zeros((MG,), jnp.uint32),
            jnp.zeros((MG,), jnp.uint32),
            jnp.zeros((MG,), jnp.int32))
    _, _, _, starts, kinds, bases, deltas, litoff = \
        lax.while_loop(cond, body, init)

    # ---- Phase 2: all-lane write_run(init, len, delta) --------------------
    marker = jnp.zeros((out_len_max + 1,), jnp.int32).at[starts].add(1)
    grp = jnp.cumsum(marker[:out_len_max]) - 1
    idx = jnp.arange(out_len_max, dtype=jnp.int32)
    k = (idx - jnp.take(starts, grp, mode="clip")).astype(jnp.uint32)
    run_v = (jnp.take(bases, grp, mode="clip")
             + jnp.take(deltas, grp, mode="clip") * k)
    lit_base = jnp.take(litoff, grp, mode="clip") + (idx - jnp.take(starts, grp, mode="clip")) * width
    lit_v = jnp.take(comp, lit_base, mode="clip").astype(jnp.uint32)
    for i in range(1, width):
        lit_v = lit_v | (jnp.take(comp, lit_base + i, mode="clip")
                         .astype(jnp.uint32) << jnp.uint32(8 * i))
    out = jnp.where(jnp.take(kinds, grp, mode="clip"), lit_v, run_v)
    out = jnp.where(idx < out_len_dyn, out, 0)
    return out.astype(dt)


def decode_chunk_scalar(comp: jnp.ndarray, out_len_dyn, out_len_max: int,
                        width: int) -> jnp.ndarray:
    """§V-E single-thread baseline: one element per loop step."""
    dt = DEV_DTYPE[width]

    def cond(s):
        return s[1] < out_len_dyn

    def body(s):
        pos, cnt, rem, val, delta, lit_mode, buf = s
        need = rem == 0
        h = st.read_byte_at(comp, pos)
        mode = h >> 6
        f = h & 63
        nxt = st.read_byte_at(comp, pos + 1)
        is_lit = mode == 2
        is_delta = mode == 1
        is_long = mode == 3
        glen = jnp.where(is_lit, f + 1,
                jnp.where(is_long, ((f << 8) | nxt) + 3, f + 3))
        val_off = pos + 1 + jnp.where(is_long, 1, 0)
        nbase = st.read_value_at(comp, val_off, width)
        ndelta = jnp.where(is_delta,
                           st.read_value_at(comp, val_off + width, width),
                           jnp.uint32(0))
        rem = jnp.where(need, glen, rem)
        lit_mode = jnp.where(need, is_lit, lit_mode)
        val = jnp.where(need & ~is_lit, nbase, val)
        delta = jnp.where(need & ~is_lit, ndelta, delta)
        hdr_adv = jnp.where(is_lit, 1,
                   jnp.where(is_delta, 1 + 2 * width,
                    jnp.where(is_long, 2 + width, 1 + width)))
        pos = jnp.where(need, pos + hdr_adv, pos)
        lit_v = st.read_value_at(comp, pos, width)
        elem = jnp.where(lit_mode, lit_v, val)
        buf = buf.at[cnt].set(elem.astype(dt))
        pos = jnp.where(lit_mode, pos + width, pos)
        val = jnp.where(lit_mode, val, val + delta)
        return pos, cnt + 1, rem - 1, val, delta, lit_mode, buf

    init = (jnp.int32(0), jnp.int32(0), jnp.int32(0), jnp.uint32(0),
            jnp.uint32(0), jnp.bool_(False), jnp.zeros((out_len_max,), dt))
    s = lax.while_loop(cond, body, init)
    return s[6]


def _kernel(comp_ref, lens_ref, out_ref, *, width: int, out_len_max: int):
    comp = comp_ref[0, :]
    out_len = lens_ref[0, 0]
    out_ref[0, :] = decode_chunk(comp, out_len, out_len_max, width)


@functools.partial(jax.jit, static_argnames=("width", "chunk_elems", "interpret"))
def decode_pallas(comp: jnp.ndarray, out_lens: jnp.ndarray, *, width: int,
                  chunk_elems: int, interpret: bool = False) -> jnp.ndarray:
    n, c = comp.shape
    dt = DEV_DTYPE[width]
    return pl.pallas_call(
        functools.partial(_kernel, width=width, out_len_max=chunk_elems),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, c), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk_elems), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, chunk_elems), dt),
        interpret=interpret,
    )(comp, out_lens.reshape(-1, 1))
