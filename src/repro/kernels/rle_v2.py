"""RLE v2 codec plugin (run / delta / literal / long-run; ORC RLE v2 spirit).

Same shape as ``rle_v1.py`` — the only code here is the Phase-1 header parse
and the Phase-2 value expression (Table II ``write_run(init, len, delta)``
for all lanes at once: out[k] = base + delta * k in wraparound uint32
arithmetic, literals via the shared multi-byte gather).  All scaffolding
lives in ``kernels/harness.py``; this is the modularity the paper's
framework claims (§IV-A).

Group structure: header h; mode = h >> 6, f = h & 63
  mode 0 -> run,      len = f+3  (3..66),   value follows
  mode 1 -> delta,    len = f+3  (3..66),   base + delta values follow
  mode 2 -> literal,  len = f+1  (1..64),   values follow
  mode 3 -> long run, len = (f<<8 | next)+3 (3..16386), value follows
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import encoders as enc
from repro.core import format as fmt
from repro.core import registry
from repro.core import streams as st
from repro.kernels import harness, ref


def max_groups(out_len: int) -> int:
    return out_len // 2 + 4


def _parse(comp, pos, width: int):
    h = st.read_byte_at(comp, pos)
    mode = h >> 6
    f = h & 63
    nxt = st.read_byte_at(comp, pos + 1)
    is_lit = mode == 2
    is_delta = mode == 1
    is_long = mode == 3
    length = jnp.where(is_lit, f + 1,
              jnp.where(is_long, ((f << 8) | nxt) + 3, f + 3))
    val_off = pos + 1 + jnp.where(is_long, 1, 0)
    return {
        "length": length,
        "advance": jnp.where(is_lit, 1 + length * width,
                    jnp.where(is_delta, 1 + 2 * width,
                     jnp.where(is_long, 2 + width, 1 + width))),
        "is_lit": is_lit,
        "base": st.read_value_at(comp, val_off, width),
        "delta": jnp.where(is_delta,
                           st.read_value_at(comp, val_off + width, width),
                           jnp.uint32(0)),
        "litoff": pos + 1,
    }


def _express(comp, f, k, width: int):
    """write_run for every lane: base + delta*k, or the k-th literal."""
    run_v = f["base"] + f["delta"] * k.astype(jnp.uint32)
    lit = st.gather_values(comp, f["litoff"] + k * width, width)
    return jnp.where(f["is_lit"], lit, run_v)


SPEC = harness.TwoPhaseSpec(
    fields=(harness.Field("is_lit", jnp.bool_),
            harness.Field("base", jnp.uint32),
            harness.Field("delta", jnp.uint32),
            harness.Field("litoff", jnp.int32)),
    parse=_parse,
    express=_express,
    max_groups=max_groups,
    max_group_len=ref.RLE2_LONG_WIN,
)


def _count_groups(row, width: int) -> int:
    pos, groups = 0, 0
    while pos < len(row):
        h = int(row[pos])
        mode, f = h >> 6, h & 63
        if mode == 2:
            pos += 1 + (f + 1) * width
        elif mode == 1:
            pos += 1 + 2 * width
        elif mode == 3:
            pos += 2 + width
        else:
            pos += 1 + width
        groups += 1
    return groups


def _demo_data(n: int, rng) -> np.ndarray:
    """Runs + arithmetic ramps (exercises run, delta, and literal modes)."""
    parts, total = [], 0
    while total < n:
        if rng.random() < 0.5:
            v = np.uint32(rng.integers(0, 1000))
            parts.append(np.full(int(rng.integers(3, 120)), v, np.uint32))
        else:
            base = rng.integers(0, 1 << 20)
            step = rng.integers(1, 64)
            m = int(rng.integers(4, 80))
            parts.append((base + step * np.arange(m, dtype=np.uint32))
                         .astype(np.uint32))
        total += len(parts[-1])
    return np.concatenate(parts)[:n]


CODEC = registry.register(registry.Codec(
    name=fmt.RLE_V2,
    encode=enc.compress_rle_v2,
    decode=harness.DecodeSpec.from_two_phase(SPEC, oracle=ref.decode_rle_v2_impl),
    plane_decompose_64=True,
    demo_data=_demo_data,
    count_groups=_count_groups,
))
