"""Two-phase decode harness — the shared machinery of every codec kernel.

This module owns everything the paper's §IV-A framework claim says a codec
author should NOT have to write:

  * Phase 1 scaffolding   — the irreducibly-sequential leader loop: one
    ``lax.while_loop`` step per compressed *group*, appending
    ``(start, <codec fields>)`` rows to VMEM group tables.
  * Phase 2 expansion     — the all-thread decode: scatter a marker at every
    group start, prefix-sum it into a lane->group map, gather each group's
    fields, and let every VPU lane evaluate the codec's value expression
    independently (Table II's vectorized ``write_run``; literals ride the
    shared multi-byte gather ``streams.gather_values``).
  * the §V-E ablation     — a generic single-thread driver emitting one
    element per loop step from the same parse/express hooks.
  * a group-serial oracle — one group per step, vector-blend write: the
    paper-faithful sequential reference, free for any two-phase codec.
  * ONE ``pallas_call``   — the generic chunk-per-grid-cell wrapper: every
    per-chunk operand gets a ``(1, row)`` BlockSpec (chunk i's HBM->VMEM DMA
    double-buffers against chunk i-1's decode — CODAG's warp-per-chunk
    provisioning), broadcast constants get index-map ``(0, 0)``.

A two-phase codec (rle_v1, rle_v2, dbp) supplies a ``TwoPhaseSpec`` — a
header parse and a value expression — and gets all four backends.  Codecs
whose Phase 2 is not lane-independent (tdeflate's LZ copies) or that need no
Phase 1 at all (bitpack) plug custom chunk bodies into the same
``DecodeSpec`` interface instead.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

DEV_DTYPE = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32}


def words_view(comp: jnp.ndarray) -> jnp.ndarray:
    """(n, C) uint8 -> (n, ceil(C/4)) uint32 little-endian word view.

    Rows whose byte width is not a multiple of 4 are zero-padded up to the
    next word boundary (trailing partial words read as if the row were
    zero-extended, which is how every bit codec's padding behaves).
    """
    n, c = comp.shape
    if c % 4:
        comp = jnp.pad(comp, ((0, 0), (0, 4 - c % 4)))
        c = comp.shape[1]
    b = comp.reshape(n, c // 4, 4).astype(jnp.uint32)
    return (b[..., 0] | (b[..., 1] << 8) | (b[..., 2] << 16) | (b[..., 3] << 24))


# --------------------------------------------------------------------------
# TwoPhaseSpec: what a group-structured codec author writes
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Field:
    """One per-group table column (beyond the harness-owned ``start``)."""

    name: str
    dtype: Any


@dataclasses.dataclass(frozen=True)
class TwoPhaseSpec:
    """Header parse + value expression; the harness supplies the rest.

    ``parse(comp, pos, width)`` reads ONE group header at byte ``pos`` and
    returns a dict with ``"length"`` (elements this group expands to),
    ``"advance"`` (total group bytes, header + payload), and one entry per
    declared field.  ``express(comp, fields, k, width)`` computes element
    ``k`` of a group from its gathered fields — it must be shape-polymorphic
    (scalar ``k`` in the single-thread driver, a lane vector in Phase 2 and
    the group-serial oracle) and return uint32.
    """

    fields: Tuple[Field, ...]
    parse: Callable[..., Dict[str, jnp.ndarray]]
    express: Callable[..., jnp.ndarray]
    max_groups: Callable[[int], int]
    max_group_len: int          # static lane-window bound (>= longest group)


def two_phase_chunk(spec: TwoPhaseSpec, comp: jnp.ndarray, out_len_dyn,
                    out_len_max: int, width: int) -> jnp.ndarray:
    """Decode one chunk with the all-thread two-phase scheme (§IV-D)."""
    MG = spec.max_groups(out_len_max)
    dt = DEV_DTYPE[width]
    names = [f.name for f in spec.fields]

    # ---- Phase 1: sequential group parse -> group tables ------------------
    def cond(s):
        return jnp.logical_and(s[2] < out_len_dyn, s[1] < MG)

    def body(s):
        pos, g, cnt, starts = s[0], s[1], s[2], s[3]
        tabs = s[4:]
        p = spec.parse(comp, pos, width)
        starts = starts.at[g].set(cnt)
        tabs = tuple(t.at[g].set(p[n]) for t, n in zip(tabs, names))
        return (pos + p["advance"], g + 1, cnt + p["length"], starts) + tabs

    init = (jnp.int32(0), jnp.int32(0), jnp.int32(0),
            jnp.full((MG,), out_len_max, jnp.int32),   # sentinel = out_len_max
            *[jnp.zeros((MG,), f.dtype) for f in spec.fields])
    final = lax.while_loop(cond, body, init)
    starts, tabs = final[3], final[4:]

    # ---- Phase 2: all-lane expansion --------------------------------------
    # lane->group map: scatter a 1 at every group start, prefix-sum.
    marker = jnp.zeros((out_len_max + 1,), jnp.int32).at[starts].add(1)
    grp = jnp.cumsum(marker[:out_len_max]) - 1
    idx = jnp.arange(out_len_max, dtype=jnp.int32)
    k = idx - jnp.take(starts, grp, mode="clip")
    fields = {n: jnp.take(t, grp, mode="clip") for n, t in zip(names, tabs)}
    out = spec.express(comp, fields, k, width)
    return jnp.where(idx < out_len_dyn, out, 0).astype(dt)


def scalar_chunk(spec: TwoPhaseSpec, comp: jnp.ndarray, out_len_dyn,
                 out_len_max: int, width: int) -> jnp.ndarray:
    """§V-E baseline: a single decode 'thread' emits one element per step —
    the serial-latency ablation, generic over any TwoPhaseSpec."""
    dt = DEV_DTYPE[width]
    names = [f.name for f in spec.fields]

    def cond(s):
        return s[1] < out_len_dyn

    def body(s):
        pos, cnt, k, rem, buf = s[0], s[1], s[2], s[3], s[4]
        cur = dict(zip(names, s[5:]))
        need = rem == 0
        p = spec.parse(comp, pos, width)
        cur = {n: jnp.where(need, p[n], cur[n]) for n in names}
        rem = jnp.where(need, p["length"], rem)
        k = jnp.where(need, 0, k)
        pos = jnp.where(need, pos + p["advance"], pos)
        v = spec.express(comp, cur, k, width)
        buf = buf.at[cnt].set(v.astype(dt))
        return (pos, cnt + 1, k + 1, rem - 1, buf) + tuple(cur[n] for n in names)

    init = (jnp.int32(0), jnp.int32(0), jnp.int32(0), jnp.int32(0),
            jnp.zeros((out_len_max,), dt),
            *[jnp.zeros((), f.dtype) for f in spec.fields])
    s = lax.while_loop(cond, body, init)
    return s[4]


def group_serial_chunk(spec: TwoPhaseSpec, comp: jnp.ndarray, out_len_dyn,
                       out_len_max: int, width: int) -> jnp.ndarray:
    """Paper-faithful sequential reference: serial across groups, vector-
    parallel within each (the warp's collaborative write, §II-B)."""
    dt = DEV_DTYPE[width]
    W = spec.max_group_len
    names = [f.name for f in spec.fields]
    lanes = jnp.arange(W, dtype=jnp.int32)

    def cond(s):
        return s[1] < out_len_dyn

    def body(s):
        pos, cnt, buf = s
        p = spec.parse(comp, pos, width)
        fields = {n: p[n] for n in names}     # scalars broadcast over lanes
        vals = spec.express(comp, fields, lanes, width).astype(dt)
        cur = lax.dynamic_slice(buf, (cnt,), (W,))
        new = jnp.where(lanes < p["length"], vals, cur)
        buf = lax.dynamic_update_slice(buf, new, (cnt,))
        return pos + p["advance"], cnt + p["length"], buf

    buf0 = jnp.zeros((out_len_max + W,), dt)
    _, _, buf = lax.while_loop(cond, body, (jnp.int32(0), jnp.int32(0), buf0))
    return buf[:out_len_max]


# --------------------------------------------------------------------------
# Epilogue: a consumer transform fused into the decode dispatch
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Epilogue:
    """Post-decode transform applied to the raw ``(num_chunks, chunk_elems)``
    matrix INSIDE the decode dispatch (same jit computation — XLA fuses the
    elementwise tail into the decode kernels, so the intermediate uint matrix
    is never materialized for consumers that don't want it).

    Hashable and static to the jit cache; array operands ride the device
    pytree under the caller-chosen ``scale_key`` / ``zero_key`` entries
    (scalars or anything broadcastable to the chunk matrix).  Application
    order:

      1. ``view_dtype``  — bitcast reinterpretation, same itemsize
                           (e.g. the uint8 decode dtype viewed as int8)
      2. ``out_dtype``   — value cast; with scale/zero set this is also the
                           compute dtype of the dequant affine (default
                           float32), i.e. the bit-width widening step
      3. zero/scale      — ``(x - zero) * scale`` (dequantization)
      4. ``fn``          — escape hatch: ``fn(out, dev) -> out`` (compared
                           by identity for jit caching)

    Dtypes are stored as strings so specs hash/compare cleanly.
    """

    view_dtype: Optional[str] = None
    out_dtype: Optional[str] = None
    scale_key: Optional[str] = None
    zero_key: Optional[str] = None
    fn: Optional[Callable[..., jnp.ndarray]] = None

    def apply(self, out: jnp.ndarray, dev: Dict[str, Any]) -> jnp.ndarray:
        if self.view_dtype is not None:
            out = jax.lax.bitcast_convert_type(out, jnp.dtype(self.view_dtype))
        if self.scale_key is not None or self.zero_key is not None:
            out = out.astype(jnp.dtype(self.out_dtype or "float32"))
            if self.zero_key is not None:
                out = out - dev[self.zero_key].astype(out.dtype)
            if self.scale_key is not None:
                out = out * dev[self.scale_key].astype(out.dtype)
        elif self.out_dtype is not None:
            out = out.astype(jnp.dtype(self.out_dtype))
        if self.fn is not None:
            out = self.fn(out, dev)
        return out


# --------------------------------------------------------------------------
# DecodeSpec: the backend-complete decode contract a codec registers
# --------------------------------------------------------------------------

BodyFn = Callable[..., jnp.ndarray]   # (inputs, consts, out_len, *, chunk_elems, width, bits)


@dataclasses.dataclass(frozen=True)
class Tunable:
    """One kernel knob a codec exposes to the offline autotuner.

    ``name`` must not collide with the framework's own knobs
    (``core.tuning.KNOWN_KNOBS``); ``candidates`` is the value grid the
    autotuner searches, ``default`` what the kernel uses when the tuned
    table has no entry and the caller passed nothing.  Values reach the
    codec's ``pallas_override`` (or the generic wrapper) through the static
    ``tune`` tuple, so they are compile-time constants to the kernel.
    """

    name: str
    candidates: Tuple[Any, ...]
    default: Any


def _default_inputs(dev: Dict[str, Any]) -> Tuple[jnp.ndarray, ...]:
    return (dev["comp"],)


def words_inputs(dev: Dict[str, Any]) -> Tuple[jnp.ndarray, ...]:
    """Chunk-input hook for bit codecs: the uint32 word view of each row."""
    words = dev.get("comp_words")
    if words is None:
        words = words_view(dev["comp"])
    return (words,)


@dataclasses.dataclass(frozen=True)
class DecodeSpec:
    """Per-backend chunk bodies plus the device-operand layout.

    Every body maps ``(inputs, consts, out_len)`` for ONE chunk to a
    ``(chunk_elems,)`` row in ``DEV_DTYPE[width]``.  ``chunk_inputs`` pulls
    the per-chunk operand arrays (leading dim = num_chunks) out of the
    device pytree; ``consts`` supplies broadcast tables replicated to every
    grid cell (Pallas kernels may not capture array constants).
    """

    body: BodyFn
    body_scalar: Optional[BodyFn] = None      # §V-E driver; falls back to body
    body_oracle: Optional[BodyFn] = None      # sequential ref; falls back to body
    chunk_inputs: Callable[[Dict[str, Any]], Tuple[jnp.ndarray, ...]] = _default_inputs
    consts: Callable[[], Tuple[jnp.ndarray, ...]] = tuple
    # optional hand-tuned pallas kernel (e.g. bitpack's output-tiled one);
    # everything else rides the generic chunk-per-grid-cell wrapper.
    pallas_override: Optional[Callable[..., jnp.ndarray]] = None
    # codec-default Epilogue fused into every dispatch unless the caller
    # passes its own (``ops.decode(..., epilogue=)`` overrides).
    epilogue: Optional[Epilogue] = None
    # kernel knobs this codec exposes to the offline autotuner
    # (``core.tuning``); values arrive via the static ``tune`` tuple.
    tunables: Tuple[Tunable, ...] = ()

    @classmethod
    def from_two_phase(cls, spec: TwoPhaseSpec,
                       oracle: Optional[Callable[..., jnp.ndarray]] = None,
                       ) -> "DecodeSpec":
        """All four backends from a parse + express pair.

        ``oracle`` optionally swaps in a handwritten sequential reference
        (signature ``(comp, out_len_dyn, out_len_max, width)``); by default
        the generic group-serial driver is used.
        """
        def body(inputs, consts, out_len, *, chunk_elems, width, bits):
            return two_phase_chunk(spec, inputs[0], out_len, chunk_elems, width)

        def body_scalar(inputs, consts, out_len, *, chunk_elems, width, bits):
            return scalar_chunk(spec, inputs[0], out_len, chunk_elems, width)

        def body_oracle(inputs, consts, out_len, *, chunk_elems, width, bits):
            fn = oracle or functools.partial(group_serial_chunk, spec)
            return fn(inputs[0], out_len, chunk_elems, width)

        return cls(body=body, body_scalar=body_scalar, body_oracle=body_oracle)


def run(spec: DecodeSpec, dev: Dict[str, Any], *, width: int,
        chunk_elems: int, backend: str, interpret: bool,
        bits: int, epilogue: Optional[Epilogue] = None,
        tune: Tuple[Tuple[str, Any], ...] = ()) -> jnp.ndarray:
    """Decode every chunk of a device table through one DecodeSpec backend.

    ``epilogue`` (caller's, falling back to the spec's default) is applied
    to the chunk matrix inside the same computation — fused by XLA into the
    dispatch, so no raw uint intermediate reaches the consumer.

    ``tune``: sorted ``((knob, value), ...)`` of kernel knobs — the generic
    wrapper's ``num_stages`` plus any codec ``Tunable``s — resolved by the
    caller (``core.tuning.kernel_tune``).  Static: new values are new
    compilations.  Kernel knobs shape only the Pallas launch; the XLA /
    scalar / oracle backends ignore them (the decoded values are knob-
    independent by the conformance gate)."""
    inputs = spec.chunk_inputs(dev)
    consts = tuple(spec.consts())
    out_lens = dev["out_lens"]
    epilogue = epilogue if epilogue is not None else spec.epilogue
    if backend == "pallas":
        kernel = spec.pallas_override or _generic_pallas
        out = kernel(spec.body, inputs, consts, out_lens,
                     chunk_elems=chunk_elems, width=width, bits=bits,
                     interpret=interpret, tune=tune)
        return epilogue.apply(out, dev) if epilogue is not None else out
    body = {"xla": spec.body,
            "scalar": spec.body_scalar or spec.body,
            "oracle": spec.body_oracle or spec.body}[backend]
    n_in = len(inputs)

    def one(*rows):
        return body(rows[:n_in], consts, rows[n_in],
                    chunk_elems=chunk_elems, width=width, bits=bits)

    out = jax.vmap(one)(*inputs, out_lens)
    return epilogue.apply(out, dev) if epilogue is not None else out


def _generic_pallas(body: BodyFn, inputs, consts, out_lens, *,
                    chunk_elems: int, width: int, bits: int,
                    interpret: bool,
                    tune: Tuple[Tuple[str, Any], ...] = ()) -> jnp.ndarray:
    """The single generic ``pallas_call`` wrapper, pipelined.

    Grid cell g decodes a *block* of ``num_stages`` consecutive chunks:
    per-chunk operands tile ``(num_stages, row)``, so one HBM->VMEM DMA
    brings the whole block in while the previous block is still decoding —
    Pallas's grid-step double buffering, with the DMA granularity (and so
    how much decode latency each transfer hides behind) exposed as the
    ``num_stages`` tunable.  ``num_stages=1`` is the original chunk-per-cell
    launch; broadcast constants replicate with a constant index map either
    way.  Under ``interpret=True`` the knob falls back to the single-stage
    path (the CPU validation grid stays exactly the hand-checked one)
    unless the ``interpret_pipeline`` tune flag forces it — how the
    conformance suite exercises the multi-stage body off-TPU.
    """
    knobs = dict(tune)
    num_stages = int(knobs.get("num_stages", 1))
    if interpret and not knobs.get("interpret_pipeline", 0):
        num_stages = 1
    n = inputs[0].shape[0]
    num_stages = max(1, min(num_stages, max(1, n)))
    pad = -n % num_stages
    if pad:
        # zero rows decode to nothing (out_lens 0 -> every body exits
        # immediately), same convention as the engine's block mode
        inputs = tuple(jnp.pad(a, ((0, pad), (0, 0))) for a in inputs)
        out_lens = jnp.pad(out_lens, (0, pad))
    n_pad = n + pad
    n_in = len(inputs)
    consts2d = [jnp.asarray(c).reshape(1, -1) for c in consts]

    def kernel(*refs):
        in_refs, lens_ref = refs[:n_in], refs[n_in]
        const_refs = refs[n_in + 1: n_in + 1 + len(consts2d)]
        out_ref = refs[-1]
        cs = tuple(r[0, :] for r in const_refs)
        for s in range(num_stages):      # unrolled: static trip count
            rows = tuple(r[s, :] for r in in_refs)
            out_ref[s, :] = body(rows, cs, lens_ref[s, 0],
                                 chunk_elems=chunk_elems, width=width,
                                 bits=bits)

    in_specs = [pl.BlockSpec((num_stages, a.shape[1]), lambda i: (i, 0))
                for a in inputs]
    in_specs.append(pl.BlockSpec((num_stages, 1), lambda i: (i, 0)))
    in_specs += [pl.BlockSpec((1, c.shape[1]), lambda i: (0, 0))
                 for c in consts2d]
    out = pl.pallas_call(
        kernel,
        grid=(n_pad // num_stages,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((num_stages, chunk_elems), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, chunk_elems),
                                       DEV_DTYPE[width]),
        interpret=interpret,
    )(*inputs, out_lens.reshape(-1, 1), *consts2d)
    return out[:n] if pad else out
