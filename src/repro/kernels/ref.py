"""Pure-jnp oracle decoders (reference semantics for every kernel).

These are the *paper-faithful* sequential decode loops, written directly on
top of the ``input_stream`` / ``output_stream`` API (core/streams.py): serial
across symbols — exactly the data dependence the paper describes (§II-B) —
with vector-parallel writes inside each symbol (the warp's collaborative
write).  They are deliberately the most obviously-correct implementations;
the Pallas kernels (rle_v1.py / rle_v2.py / tdeflate.py / bitpack.py) use the
two-phase vectorized scheme and are validated against these oracles.

All functions operate on a SINGLE chunk with static bounds; callers vmap
across chunks (chunk-parallelism, §II-B).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import streams as st
from repro.core import encoders as enc
from repro.kernels.harness import DEV_DTYPE  # noqa: F401  (shared dtype map)

# deflate tables as jnp constants
LEN_EXTRA = jnp.asarray(enc.LEN_EXTRA)
LEN_BASE = jnp.asarray(enc.LEN_BASE)
DIST_EXTRA = jnp.asarray(enc.DIST_EXTRA)
DIST_BASE = jnp.asarray(enc.DIST_BASE)

MAX_MATCH_WIN = 272          # >= MAX_MATCH (258), slack for the blend window
RLE1_MAX_WIN = 132           # >= 130
RLE2_LONG_WIN = enc.RLE2_MAX_LONG + 2
RLE2_LIT_WIN = enc.RLE2_MAX_LIT


def _write_values(s: st.OutStream, vals: jnp.ndarray, length,
                  max_len: int) -> st.OutStream:
    """Blend ``length`` precomputed values into the output at pos."""
    idx = jnp.arange(max_len, dtype=jnp.int32)
    cur = lax.dynamic_slice(s.buf, (s.pos,), (max_len,))
    new = jnp.where(idx < length, vals.astype(s.buf.dtype), cur)
    return s._replace(buf=lax.dynamic_update_slice(s.buf, new, (s.pos,)),
                      pos=s.pos + length.astype(jnp.int32))


# --------------------------------------------------------------------------
# RLE v1 oracle
# --------------------------------------------------------------------------


def decode_rle_v1_impl(comp: jnp.ndarray, out_len_dyn, out_len_max: int,
                       width: int) -> jnp.ndarray:
    """comp: (>=comp_len+4,) uint8 padded. Returns (out_len_max,) dev dtype."""
    dt = DEV_DTYPE[width]
    out = st.outstream(out_len_max + RLE1_MAX_WIN, dt)
    lit_idx = jnp.arange(128, dtype=jnp.int32)

    def cond(state):
        pos, s = state
        return s.pos < out_len_dyn

    def body(state):
        pos, s = state
        c = st.read_byte_at(comp, pos)
        is_run = c < 128
        run_len = c + 3
        lit_len = 256 - c
        val = st.read_value_at(comp, pos + 1, width)
        s_run = st.write_run(s, val, run_len, jnp.uint32(0), RLE1_MAX_WIN)
        lit_vals = st.gather_values(comp, pos + 1 + lit_idx * width, width)
        s_lit = _write_values(s, jnp.pad(lit_vals, (0, RLE1_MAX_WIN - 128)),
                              lit_len, RLE1_MAX_WIN)
        s = jax.tree.map(lambda a, b: jnp.where(is_run, a, b), s_run, s_lit)
        pos = pos + 1 + jnp.where(is_run, width, lit_len * width)
        return pos, s

    _, s = lax.while_loop(cond, body, (jnp.int32(0), out))
    return s.buf[:out_len_max]


@functools.partial(jax.jit, static_argnums=(1, 2))
def decode_rle_v1(comp: jnp.ndarray, out_len: int, width: int) -> jnp.ndarray:
    return decode_rle_v1_impl(comp, jnp.int32(out_len), out_len, width)


# --------------------------------------------------------------------------
# RLE v2 oracle
# --------------------------------------------------------------------------


def decode_rle_v2_impl(comp: jnp.ndarray, out_len_dyn, out_len_max: int,
                       width: int) -> jnp.ndarray:
    dt = DEV_DTYPE[width]
    out = st.outstream(out_len_max + RLE2_LONG_WIN, dt)
    lit_idx = jnp.arange(RLE2_LIT_WIN, dtype=jnp.int32)

    def cond(state):
        pos, s = state
        return s.pos < out_len_dyn

    def body(state):
        pos, s = state
        h = st.read_byte_at(comp, pos)
        mode = h >> 6
        f = h & 63
        nxt = st.read_byte_at(comp, pos + 1)
        is_run = mode == 0
        is_delta = mode == 1
        is_lit = mode == 2
        is_long = mode == 3
        length = jnp.where(is_lit, f + 1,
                  jnp.where(is_long, ((f << 8) | nxt) + 3, f + 3))
        val_off = pos + 1 + jnp.where(is_long, 1, 0)
        base = st.read_value_at(comp, val_off, width)
        delta = jnp.where(is_delta,
                          st.read_value_at(comp, val_off + width, width),
                          jnp.uint32(0))
        # run/delta/long-run all expand as init + delta*k (delta==0 for runs)
        s_run = st.write_run(s, base, length, delta, RLE2_LONG_WIN)
        lit_vals = st.gather_values(comp, pos + 1 + lit_idx * width, width)
        s_lit = _write_values(
            s, jnp.pad(lit_vals, (0, RLE2_LONG_WIN - RLE2_LIT_WIN)),
            length, RLE2_LONG_WIN)
        s = jax.tree.map(lambda a, b: jnp.where(is_lit, b, a), s_run, s_lit)
        adv = jnp.where(is_lit, 1 + length * width,
               jnp.where(is_delta, 1 + 2 * width,
                jnp.where(is_long, 2 + width, 1 + width)))
        return pos + adv, s

    _, s = lax.while_loop(cond, body, (jnp.int32(0), out))
    return s.buf[:out_len_max]


@functools.partial(jax.jit, static_argnums=(1, 2))
def decode_rle_v2(comp: jnp.ndarray, out_len: int, width: int) -> jnp.ndarray:
    return decode_rle_v2_impl(comp, jnp.int32(out_len), out_len, width)


# --------------------------------------------------------------------------
# tdeflate oracle (classic inflate loop: huffman -> literal | (len,dist) copy)
# --------------------------------------------------------------------------


def decode_tdeflate_impl(words: jnp.ndarray, lut_lsym: jnp.ndarray,
                         lut_lbits: jnp.ndarray, lut_dsym: jnp.ndarray,
                         lut_dbits: jnp.ndarray, out_len_dyn,
                         out_len_max: int) -> jnp.ndarray:
    """words: (>=n_words+2,) uint32 LSB-first bitstream. uint8[out_len_max]."""
    out = st.outstream(out_len_max + MAX_MATCH_WIN, jnp.uint8)
    bs0 = st.bitstream(words)

    def cond(state):
        bs, s, done = state
        return jnp.logical_and(~done, s.pos < out_len_dyn)

    def body(state):
        bs, s, done = state
        v = st.peek_bits(bs, enc.MAX_CODE_BITS)
        sym = jnp.take(lut_lsym, v.astype(jnp.int32), mode="clip").astype(jnp.int32)
        nb = jnp.take(lut_lbits, v.astype(jnp.int32), mode="clip").astype(jnp.int32)
        is_lit = (sym < 256) & (nb > 0)
        # nb == 0 marks an invalid/padding code word: stop (corrupt guard)
        is_eob = (sym == 256) | (nb == 0)
        is_match = (sym > 256) & (nb > 0)
        # ---- match decode (computed unconditionally, selected at the end)
        lc = jnp.clip(sym - 257, 0, 28)
        bs_m = st.skip_bits(bs, nb)
        eb = jnp.take(LEN_EXTRA, lc)
        extra = st.peek_bits(bs_m, eb)
        length = jnp.take(LEN_BASE, lc).astype(jnp.uint32) + extra
        bs_m = st.skip_bits(bs_m, eb)
        dv = st.peek_bits(bs_m, enc.MAX_CODE_BITS)
        dsym = jnp.take(lut_dsym, dv.astype(jnp.int32), mode="clip").astype(jnp.int32)
        dnb = jnp.take(lut_dbits, dv.astype(jnp.int32), mode="clip").astype(jnp.int32)
        bs_m = st.skip_bits(bs_m, dnb)
        deb = jnp.take(DIST_EXTRA, dsym)
        dextra = st.peek_bits(bs_m, deb)
        dist = jnp.take(DIST_BASE, dsym).astype(jnp.uint32) + dextra
        bs_m = st.skip_bits(bs_m, deb)
        s_match = st.memcpy(s, dist, length, MAX_MATCH_WIN)
        # ---- literal
        s_lit = st.write_byte(s, (sym & 0xFF).astype(jnp.uint8))
        s_new = jax.tree.map(
            lambda a, b, c: jnp.where(is_lit, a, jnp.where(is_match, b, c)),
            s_lit, s_match, s)
        bs_lit = st.skip_bits(bs, nb)
        bs_new = jax.tree.map(lambda a, b: jnp.where(is_match, a, b), bs_m, bs_lit)
        return bs_new, s_new, jnp.logical_or(done, is_eob)

    _, s, _ = lax.while_loop(cond, body, (bs0, out, jnp.bool_(False)))
    return s.buf[:out_len_max]


@functools.partial(jax.jit, static_argnums=(5,))
def decode_tdeflate(words: jnp.ndarray, lut_lsym: jnp.ndarray,
                    lut_lbits: jnp.ndarray, lut_dsym: jnp.ndarray,
                    lut_dbits: jnp.ndarray, out_len: int) -> jnp.ndarray:
    return decode_tdeflate_impl(words, lut_lsym, lut_lbits, lut_dsym,
                                lut_dbits, jnp.int32(out_len), out_len)


# --------------------------------------------------------------------------
# bitpack oracle
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(1, 2))
def unpack_bits(words: jnp.ndarray, out_len: int, bits: int) -> jnp.ndarray:
    """words: (>=nwords+1,) uint32. Returns uint32[out_len] (values < 2^bits)."""
    idx = jnp.arange(out_len, dtype=jnp.int32)
    bitpos = idx * bits
    w = bitpos >> 5
    off = (bitpos & 31).astype(jnp.uint32)
    w0 = jnp.take(words, w, mode="clip")
    w1 = jnp.take(words, w + 1, mode="clip")
    lo = jnp.right_shift(w0, off)
    sh = (jnp.uint32(32) - off) & jnp.uint32(31)
    hi = jnp.where(off > 0, jnp.left_shift(w1, sh), jnp.uint32(0))
    mask = jnp.uint32((1 << bits) - 1) if bits < 32 else jnp.uint32(0xFFFFFFFF)
    return (lo | hi) & mask
