"""RLE v1 decode — Pallas TPU kernel (chunk-per-grid-cell, two-phase).

CODAG mapping (DESIGN.md §2):
  * grid = chunks                -> warp-level provisioning: every chunk is an
    independent decompression stream; Pallas double-buffers the HBM->VMEM DMA
    of chunk i+1 against the decode of chunk i (the scheduler-level latency
    hiding the paper obtains from many resident warps).
  * Phase 1 (group parse)        -> the irreducibly-sequential leader loop,
    one `lax.while_loop` step per *group* (not per element): control byte ->
    (start, kind, value, literal offset) appended to a VMEM group table.
  * Phase 2 (expansion)          -> the all-thread decode: every VPU lane
    independently computes its element from (init, delta, lane) — the
    vectorized `write_run` of Table II — via a scatter/cumsum group-id map
    and table gathers.  No synchronization, no broadcasts.

VMEM budget: a 128 KiB uncompressed chunk (32Ki u32 elems) uses
  comp (<=128K) + out (128K) + 4 group tables (2*out_len ints = 512K)
  ~= 1 MiB << VMEM.  BlockSpecs below tile exactly one chunk per cell.

Validated in interpret mode against the sequential oracle (kernels/ref.py);
scalar single-thread variant (`decode_chunk_scalar`) implements the paper's
§V-E ablation baseline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core import streams as st
from repro.kernels.ref import DEV_DTYPE


def max_groups(out_len: int) -> int:
    # worst case: [run(3), lit(1)] repeating = 2 groups / 4 elements
    return out_len // 2 + 4


# --------------------------------------------------------------------------
# shared two-phase chunk decode body (used by the XLA backend and the kernel)
# --------------------------------------------------------------------------


def decode_chunk(comp: jnp.ndarray, out_len_dyn, out_len_max: int,
                 width: int) -> jnp.ndarray:
    """Decode one chunk. comp uint8 (padded), returns (out_len_max,)."""
    MG = max_groups(out_len_max)
    dt = DEV_DTYPE[width]

    # ---- Phase 1: sequential group parse ---------------------------------
    def cond(s):
        pos, g, cnt = s[0], s[1], s[2]
        return jnp.logical_and(cnt < out_len_dyn, g < MG)

    def body(s):
        pos, g, cnt, starts, isrun, vals, litoff = s
        c = st.read_byte_at(comp, pos)
        is_run = c < 128
        length = jnp.where(is_run, c + 3, 256 - c)
        v = st.read_value_at(comp, pos + 1, width)
        starts = starts.at[g].set(cnt)
        isrun = isrun.at[g].set(is_run)
        vals = vals.at[g].set(v)
        litoff = litoff.at[g].set(pos + 1)
        pos = pos + 1 + jnp.where(is_run, width, length * width)
        return pos, g + 1, cnt + length, starts, isrun, vals, litoff

    init = (jnp.int32(0), jnp.int32(0), jnp.int32(0),
            jnp.full((MG,), out_len_max, jnp.int32),   # sentinel = out_len_max
            jnp.zeros((MG,), jnp.bool_),
            jnp.zeros((MG,), jnp.uint32),
            jnp.zeros((MG,), jnp.int32))
    _, _, _, starts, isrun, vals, litoff = lax.while_loop(cond, body, init)

    # ---- Phase 2: all-lane expansion -------------------------------------
    # group-id map: scatter a 1 at every group start, prefix-sum.
    marker = jnp.zeros((out_len_max + 1,), jnp.int32).at[starts].add(1)
    grp = jnp.cumsum(marker[:out_len_max]) - 1
    idx = jnp.arange(out_len_max, dtype=jnp.int32)
    g_start = jnp.take(starts, grp, mode="clip")
    k = idx - g_start
    run_v = jnp.take(vals, grp, mode="clip")
    lit_base = jnp.take(litoff, grp, mode="clip") + k * width
    lit_v = jnp.take(comp, lit_base, mode="clip").astype(jnp.uint32)
    for i in range(1, width):
        lit_v = lit_v | (jnp.take(comp, lit_base + i, mode="clip")
                         .astype(jnp.uint32) << jnp.uint32(8 * i))
    out = jnp.where(jnp.take(isrun, grp, mode="clip"), run_v, lit_v)
    out = jnp.where(idx < out_len_dyn, out, 0)
    return out.astype(dt)


# --------------------------------------------------------------------------
# §V-E ablation: single-thread decoding (one element per loop step)
# --------------------------------------------------------------------------


def decode_chunk_scalar(comp: jnp.ndarray, out_len_dyn, out_len_max: int,
                        width: int) -> jnp.ndarray:
    """Paper §V-E baseline: a single decode 'thread' emits one element per
    step — exposes the serial latency CODAG's all-thread scheme removes."""
    dt = DEV_DTYPE[width]

    def cond(s):
        return s[1] < out_len_dyn

    def body(s):
        pos, cnt, rem, val, lit_mode, buf = s
        # parse a new group header when the current one is exhausted
        need = rem == 0
        c = st.read_byte_at(comp, pos)
        is_run = c < 128
        glen = jnp.where(is_run, c + 3, 256 - c)
        rem = jnp.where(need, glen, rem)
        lit_mode = jnp.where(need, ~is_run, lit_mode)
        val_pos = jnp.where(need & is_run, pos + 1, 0)
        new_val = st.read_value_at(comp, val_pos, width)
        val = jnp.where(need & is_run, new_val, val)
        # literal cursor: after header, comp pos points at this elem's bytes
        pos = jnp.where(need, pos + 1 + jnp.where(is_run, width, 0), pos)
        lit_v = st.read_value_at(comp, pos, width)
        elem = jnp.where(lit_mode, lit_v, val)
        buf = buf.at[cnt].set(elem.astype(dt))
        pos = jnp.where(lit_mode, pos + width, pos)
        return pos, cnt + 1, rem - 1, val, lit_mode, buf

    init = (jnp.int32(0), jnp.int32(0), jnp.int32(0), jnp.uint32(0),
            jnp.bool_(False), jnp.zeros((out_len_max,), dt))
    s = lax.while_loop(cond, body, init)
    return s[5]


# --------------------------------------------------------------------------
# Pallas kernel
# --------------------------------------------------------------------------


def _kernel(comp_ref, lens_ref, out_ref, *, width: int, out_len_max: int):
    comp = comp_ref[0, :]
    out_len = lens_ref[0, 0]
    out_ref[0, :] = decode_chunk(comp, out_len, out_len_max, width)


@functools.partial(jax.jit, static_argnames=("width", "chunk_elems", "interpret"))
def decode_pallas(comp: jnp.ndarray, out_lens: jnp.ndarray, *, width: int,
                  chunk_elems: int, interpret: bool = False) -> jnp.ndarray:
    """comp: (num_chunks, C) uint8, out_lens: (num_chunks,) int32."""
    n, c = comp.shape
    dt = DEV_DTYPE[width]
    return pl.pallas_call(
        functools.partial(_kernel, width=width, out_len_max=chunk_elems),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, c), lambda i: (i, 0)),       # chunk bytes -> VMEM
            pl.BlockSpec((1, 1), lambda i: (i, 0)),       # per-chunk length
        ],
        out_specs=pl.BlockSpec((1, chunk_elems), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, chunk_elems), dt),
        interpret=interpret,
    )(comp, out_lens.reshape(-1, 1))
