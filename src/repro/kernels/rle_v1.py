"""RLE v1 codec plugin (byte-aligned runs + literals; ORC RLE v1 structure).

Everything below is exactly what the paper's §IV-A framework claim says a
codec author writes: a Phase-1 header parse and a Phase-2 value expression.
The while-loop group-table scaffolding, the scatter/cumsum/gather all-thread
expansion, the §V-E single-thread ablation, and the Pallas chunk-per-cell
wrapper all live in ``kernels/harness.py``; the host encoder is
``encoders.compress_rle_v1``; the sequential oracle stays in
``kernels/ref.py``.

Group structure (DESIGN.md §2):
  control c in [0,127]   -> run of length c+3 (3..130), one value follows
  control c in [128,255] -> 256-c literals (1..128), values follow
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import encoders as enc
from repro.core import format as fmt
from repro.core import registry
from repro.core import streams as st
from repro.kernels import harness, ref


def max_groups(out_len: int) -> int:
    # worst case: [run(3), lit(1)] repeating = 2 groups / 4 elements
    return out_len // 2 + 4


def _parse(comp, pos, width: int):
    """Control byte -> (length, advance, kind, value, literal offset)."""
    c = st.read_byte_at(comp, pos)
    is_run = c < 128
    length = jnp.where(is_run, c + 3, 256 - c)
    return {
        "length": length,
        "advance": 1 + jnp.where(is_run, width, length * width),
        "is_run": is_run,
        "value": st.read_value_at(comp, pos + 1, width),
        "litoff": pos + 1,
    }


def _express(comp, f, k, width: int):
    """Element k of a group: the run value, or the k-th gathered literal."""
    lit = st.gather_values(comp, f["litoff"] + k * width, width)
    return jnp.where(f["is_run"], f["value"], lit)


SPEC = harness.TwoPhaseSpec(
    fields=(harness.Field("is_run", jnp.bool_),
            harness.Field("value", jnp.uint32),
            harness.Field("litoff", jnp.int32)),
    parse=_parse,
    express=_express,
    max_groups=max_groups,
    max_group_len=ref.RLE1_MAX_WIN,
)


def _count_groups(row, width: int) -> int:
    """Host-side header walk (Table V avg symbol length)."""
    pos, groups = 0, 0
    while pos < len(row):
        c = int(row[pos])
        pos += 1 + (width if c < 128 else (256 - c) * width)
        groups += 1
    return groups


def _demo_data(n: int, rng) -> np.ndarray:
    """Run-heavy uint32 stream (the codec's natural workload)."""
    vals = rng.integers(0, 100, max(4, n // 50)).astype(np.uint32)
    return np.resize(np.repeat(vals, rng.integers(1, 100, len(vals))), n)


CODEC = registry.register(registry.Codec(
    name=fmt.RLE_V1,
    encode=enc.compress_rle_v1,
    decode=harness.DecodeSpec.from_two_phase(SPEC, oracle=ref.decode_rle_v1_impl),
    plane_decompose_64=True,
    demo_data=_demo_data,
    count_groups=_count_groups,
))
