"""tdeflate (Deflate-semantics) decode — Pallas TPU kernel.

Two-phase decode/execute split (the same split RAPIDS' leader-thread decode /
collaborative write uses, and the reason the paper only gains 1.18x on
Deflate — the Huffman stage is irreducibly serial):

  Phase 1 (serial per chunk): table-driven Huffman token parse
      12-bit LSB-first peek -> flat LUT -> (symbol, nbits); extra bits for
      lengths/distances.  Consecutive literals are batched into `litrun`
      commands whose bytes land in a contiguous side buffer, so Phase 2's
      writes are wide even for literal-heavy streams.
  Phase 2 (serial across commands, vector-parallel within): Table II
      primitives — `write_from` for literal runs and the overlap-safe
      `memcpy` (Alg. 2, circular window when len > dist) for LZ matches.

Chunk-level parallelism comes from the harness's generic chunk-per-grid-cell
``pallas_call`` wrapper, exactly CODAG's warp-per-chunk provisioning.  The
Phase-2 command execution here is serial-with-vector-writes (LZ copies
depend on earlier output), so this codec plugs its own chunk bodies into the
``DecodeSpec`` interface instead of a ``TwoPhaseSpec``; the deflate
base/extra tables ride the wrapper's broadcast-constant lane.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import encoders as enc
from repro.core import format as fmt
from repro.core import registry
from repro.core import streams as st
from repro.kernels import harness, ref

LEN_EXTRA = jnp.asarray(enc.LEN_EXTRA)
LEN_BASE = jnp.asarray(enc.LEN_BASE)
DIST_EXTRA = jnp.asarray(enc.DIST_EXTRA)
DIST_BASE = jnp.asarray(enc.DIST_BASE)

LITRUN_CAP = 256          # max literals batched into one command
CMD_WIN = 272             # blend window >= max(MAX_MATCH=258, LITRUN_CAP)


def max_cmds(out_len: int) -> int:
    # worst case: alternating match(>=3) + litrun(>=1) = 2 cmds / 4 bytes
    return out_len // 2 + 4


def decode_chunk(words: jnp.ndarray, lut_lsym: jnp.ndarray,
                 lut_lbits: jnp.ndarray, lut_dsym: jnp.ndarray,
                 lut_dbits: jnp.ndarray, out_len_dyn,
                 out_len_max: int, tables=None) -> jnp.ndarray:
    # deflate base/extra tables; passed in explicitly from the Pallas kernel
    # (kernels may not capture array constants), defaulted elsewhere.
    LEN_EXTRA_, LEN_BASE_, DIST_EXTRA_, DIST_BASE_ = (
        tables if tables is not None
        else (LEN_EXTRA, LEN_BASE, DIST_EXTRA, DIST_BASE))
    MC = max_cmds(out_len_max)

    # ---- Phase 1: Huffman token parse -> command list ---------------------
    def cond(s):
        bs, ci, out_cnt, done = s[0], s[1], s[2], s[6]
        return jnp.logical_and(~done,
               jnp.logical_and(out_cnt < out_len_dyn, ci < MC))

    def body(s):
        (bs, ci, out_cnt, lit_cnt, open_lit, lits, done,
         kinds, cmd_a, cmd_b) = s
        v = st.peek_bits(bs, enc.MAX_CODE_BITS)
        sym = jnp.take(lut_lsym, v.astype(jnp.int32), mode="clip")
        nb = jnp.take(lut_lbits, v.astype(jnp.int32), mode="clip")
        is_lit = (sym < 256) & (nb > 0)
        is_eob = (sym == 256) | (nb == 0)   # nb==0: invalid code, stop
        is_match = (sym > 256) & (nb > 0)
        # match decode (unconditional compute, masked advance)
        lc = jnp.clip(sym - 257, 0, 28)
        bs_m = st.skip_bits(bs, nb)
        eb = jnp.take(LEN_EXTRA_, lc)
        length = jnp.take(LEN_BASE_, lc) + st.peek_bits(bs_m, eb).astype(jnp.int32)
        bs_m = st.skip_bits(bs_m, eb)
        dv = st.peek_bits(bs_m, enc.MAX_CODE_BITS)
        dsym = jnp.take(lut_dsym, dv.astype(jnp.int32), mode="clip")
        dnb = jnp.take(lut_dbits, dv.astype(jnp.int32), mode="clip")
        bs_m = st.skip_bits(bs_m, dnb)
        deb = jnp.take(DIST_EXTRA_, dsym)
        dist = jnp.take(DIST_BASE_, dsym) + st.peek_bits(bs_m, deb).astype(jnp.int32)
        bs_m = st.skip_bits(bs_m, deb)
        # literal bookkeeping
        lits = lits.at[lit_cnt].set((sym & 0xFF).astype(jnp.uint8))
        prev_b = jnp.take(cmd_b, ci - 1, mode="clip")
        prev_a = jnp.take(cmd_a, ci - 1, mode="clip")
        extend = open_lit & is_lit & (prev_b < LITRUN_CAP) & (ci > 0)
        # where to write this token's command
        slot = jnp.where(extend, ci - 1, ci)
        new_kind = is_match
        new_a = jnp.where(is_match, dist,
                          jnp.where(extend, prev_a, lit_cnt))
        new_b = jnp.where(is_match, length,
                          jnp.where(extend, prev_b + 1, 1))
        do_write = ~is_eob
        wslot = jnp.where(do_write, slot, MC)        # OOB write drops
        kinds = kinds.at[wslot].set(new_kind)
        cmd_a = cmd_a.at[wslot].set(new_a)
        cmd_b = cmd_b.at[wslot].set(new_b)
        ci = ci + jnp.where(do_write & ~extend, 1, 0)
        lit_cnt = lit_cnt + jnp.where(is_lit, 1, 0)
        out_cnt = out_cnt + jnp.where(is_lit, 1, jnp.where(is_match, length, 0))
        open_lit = is_lit
        bs = jax.tree.map(lambda a, b: jnp.where(is_match, a, b),
                          bs_m, st.skip_bits(bs, nb))
        return (bs, ci, out_cnt, lit_cnt, open_lit, lits,
                done | is_eob, kinds, cmd_a, cmd_b)

    init = (st.bitstream(words), jnp.int32(0), jnp.int32(0), jnp.int32(0),
            jnp.bool_(False), jnp.zeros((out_len_max + CMD_WIN,), jnp.uint8),
            jnp.bool_(False),
            jnp.zeros((MC,), jnp.bool_),
            jnp.zeros((MC,), jnp.int32),
            jnp.zeros((MC,), jnp.int32))
    s = lax.while_loop(cond, body, init)
    n_cmds, lits, kinds, cmd_a, cmd_b = s[1], s[5], s[7], s[8], s[9]

    # ---- Phase 2: execute commands (Table II writes) ----------------------
    out0 = st.outstream(out_len_max + CMD_WIN, jnp.uint8)

    def cond2(s2):
        i, out = s2
        return jnp.logical_and(i < n_cmds, out.pos < out_len_dyn)

    def body2(s2):
        i, out = s2
        kind = jnp.take(kinds, i, mode="clip")
        a = jnp.take(cmd_a, i, mode="clip")
        b = jnp.take(cmd_b, i, mode="clip")
        out_m = st.memcpy(out, a, b, CMD_WIN)
        out_l = st.write_from(out, lits, a, b, CMD_WIN)
        out = jax.tree.map(lambda x, y: jnp.where(kind, x, y), out_m, out_l)
        return i + 1, out

    _, out = lax.while_loop(cond2, body2, (jnp.int32(0), out0))
    idx = jnp.arange(out_len_max, dtype=jnp.int32)
    return jnp.where(idx < out_len_dyn, out.buf[:out_len_max], 0)


def decode_chunk_scalar(words, lut_lsym, lut_lbits, lut_dsym, lut_dbits,
                        out_len_dyn, out_len_max: int) -> jnp.ndarray:
    """§V-E single-thread baseline: one output byte per loop step (match
    copies proceed byte-by-byte through a scalar back-reference cursor)."""
    def cond(s):
        pos, done = s[1], s[6]
        return jnp.logical_and(~done, pos < out_len_dyn)

    def body(s):
        bs, pos, rem, src, is_m, buf, done = s
        need = rem == 0
        # decode next token only when needed
        v = st.peek_bits(bs, enc.MAX_CODE_BITS)
        sym = jnp.take(lut_lsym, v.astype(jnp.int32), mode="clip")
        nb = jnp.take(lut_lbits, v.astype(jnp.int32), mode="clip")
        is_lit = (sym < 256) & (nb > 0)
        is_eob = (sym == 256) | (nb == 0)
        lc = jnp.clip(sym - 257, 0, 28)
        bs_m = st.skip_bits(bs, nb)
        eb = jnp.take(LEN_EXTRA, lc)
        length = jnp.take(LEN_BASE, lc) + st.peek_bits(bs_m, eb).astype(jnp.int32)
        bs_m = st.skip_bits(bs_m, eb)
        dv = st.peek_bits(bs_m, enc.MAX_CODE_BITS)
        dsym = jnp.take(lut_dsym, dv.astype(jnp.int32), mode="clip")
        dnb = jnp.take(lut_dbits, dv.astype(jnp.int32), mode="clip")
        bs_m = st.skip_bits(bs_m, dnb)
        deb = jnp.take(DIST_EXTRA, dsym)
        dist = jnp.take(DIST_BASE, dsym) + st.peek_bits(bs_m, deb).astype(jnp.int32)
        bs_m = st.skip_bits(bs_m, deb)
        bs_lit = st.skip_bits(bs, nb)
        new_is_m = (sym > 256) & (nb > 0)
        rem = jnp.where(need, jnp.where(is_lit, 1, length), rem)
        is_m = jnp.where(need, new_is_m, is_m)
        src = jnp.where(need, jnp.where(new_is_m, pos - dist, 0), src)
        lit_byte = (sym & 0xFF).astype(jnp.uint8)
        copy_byte = jnp.take(buf, src, mode="clip")
        # freeze token decode state when mid-copy
        bs = jax.tree.map(
            lambda new_m, new_l, old: jnp.where(
                need, jnp.where(new_is_m, new_m, new_l), old),
            bs_m, bs_lit, bs)
        done = done | (need & is_eob)
        emit = ~(need & is_eob)
        wpos = jnp.where(emit, pos, out_len_max + 8)
        buf = buf.at[wpos].set(jnp.where(is_m, copy_byte,
                                         jnp.where(need, lit_byte,
                                                   copy_byte)))
        pos = pos + jnp.where(emit, 1, 0)
        rem = rem - jnp.where(emit, 1, 0)
        src = src + 1
        return bs, pos, rem, src, is_m, buf, done

    init = (st.bitstream(words), jnp.int32(0), jnp.int32(0), jnp.int32(0),
            jnp.bool_(False), jnp.zeros((out_len_max + 16,), jnp.uint8),
            jnp.bool_(False))
    s = lax.while_loop(cond, body, init)
    return s[5][:out_len_max]


# --------------------------------------------------------------------------
# registry plumbing: device operands + the DecodeSpec bodies
# --------------------------------------------------------------------------


def _chunk_inputs(dev):
    """Per-chunk operands: the word stream plus the four per-chunk LUTs."""
    words = dev.get("comp_words")
    if words is None:
        words = harness.words_view(dev["comp"])
    return (words,) + tuple(dev[k].astype(jnp.int32) for k in
                            ("lut_lsym", "lut_lbits", "lut_dsym", "lut_dbits"))


def _body(inputs, consts, out_len, *, chunk_elems, width, bits):
    words, ls, lb, ds, db = inputs
    return decode_chunk(words, ls, lb, ds, db, out_len, chunk_elems,
                        tables=consts or None)


def _body_scalar(inputs, consts, out_len, *, chunk_elems, width, bits):
    words, ls, lb, ds, db = inputs
    return decode_chunk_scalar(words, ls, lb, ds, db, out_len, chunk_elems)


def _body_oracle(inputs, consts, out_len, *, chunk_elems, width, bits):
    words, ls, lb, ds, db = inputs
    return ref.decode_tdeflate_impl(words, ls, lb, ds, db, out_len, chunk_elems)


def _demo_data(n, rng):
    """Repetitive text bytes (LZ matches + skewed literal frequencies)."""
    motifs = [b"the quick brown fox ", b"abcabcabc", b"codag streams "]
    out = bytearray()
    while len(out) < n:
        out += motifs[int(rng.integers(0, len(motifs)))]
    return np.frombuffer(bytes(out[:n]), np.uint8).copy()


CODEC = registry.register(registry.Codec(
    name=fmt.TDEFLATE,
    encode=enc.compress_tdeflate,
    decode=harness.DecodeSpec(
        body=_body,
        body_scalar=_body_scalar,
        body_oracle=_body_oracle,
        chunk_inputs=_chunk_inputs,
        consts=lambda: (LEN_EXTRA, LEN_BASE, DIST_EXTRA, DIST_BASE),
    ),
    needs_words=True,
    byte_stream=True,
    demo_data=_demo_data,
))
