"""Multi-byte LZSS (GPULZ-style, arXiv 2304.07342) — element-granular LZ.

Where tdeflate works on bytes with Huffman-coded tokens, lzss trades ratio
for parallel decode: tokens are byte-aligned, and matches/literals are in
*element* units (the blob's width — GPULZ's "multi-byte" granularity), so
a u32 stream's matches never split an element.

Token stream, width = element bytes:

  control c in [0, 127]   -> literal run of c+1 elements (1..128);
                             (c+1)*width little-endian value bytes follow
  control c in [128, 255] -> match of c-128+MIN_MATCH elements (2..129);
                             u16 LE distance in elements follows
                             (1 <= dist <= 65535, chunk-local window)

Decode is the paper's two-phase split, with GPULZ's twist that Phase 1 is
an offset prefix sum and Phase 2 is an all-thread copy:

  Phase 1 (serial leader loop): parse one token per step into
      (start, is_match, dist, litoff) group tables — the output-offset
      prefix sum falls out of the running ``start`` counter.
  Phase 2 (all-thread): marker-scatter/cumsum maps every output lane to
      its token.  Back-references may point into other matches (and
      overlap their own output), so the per-lane source is resolved by
      pointer doubling — ``ptr = ptr[ptr]``, ``ceil(log2(chunk_elems))``
      rounds: every chain strictly decreases and terminates at a literal
      lane, after which ONE vectorized ``streams.gather_values`` reads
      every lane's value from the compressed stream.  No serial command
      loop: Phase 2 is all-thread, like the paper's RLE expansion.

The §V-E scalar body decodes one element per step with a scalar
back-reference cursor; the oracle is the classic serial token walk using
the Table II ``memcpy`` (overlap-safe circular window).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import encoders as enc
from repro.core import format as fmt
from repro.core import registry
from repro.core import streams as st
from repro.kernels import harness

LZSS = "lzss"

MIN_MATCH = 2
MAX_MATCH = MIN_MATCH + 127   # 129 elements
MAX_LIT = 128
MAX_DIST = 65535
CW = 132                      # oracle blend window >= max(MAX_MATCH, MAX_LIT)


def max_tokens(out_len: int) -> int:
    return out_len + 4        # every token emits >= 1 element


# --------------------------------------------------------------------------
# host encoder: greedy hash-of-2 chain over elements (single probe)
# --------------------------------------------------------------------------


def encode_lzss_chunk(x: np.ndarray, width: int) -> bytes:
    xs = np.ascontiguousarray(x).astype(np.uint32)
    vals = xs.tolist()
    n = len(vals)
    out = bytearray()
    head: dict = {}

    def flush(lo: int, hi: int) -> None:
        i = lo
        while i < hi:
            k = min(MAX_LIT, hi - i)
            out.append(k - 1)
            out.extend(enc._values_bytes(xs[i:i + k], width))
            i += k

    i, lit = 0, 0
    while i < n:
        m, dist = 0, 0
        if i + MIN_MATCH <= n:
            key = (vals[i], vals[i + 1])
            cand = head.get(key, -1)
            head[key] = i
            if cand >= 0 and i - cand <= MAX_DIST:
                lim = min(MAX_MATCH, n - i)
                while m < lim and vals[cand + m] == vals[i + m]:
                    m += 1
                dist = i - cand
        # profitable only if the 3 token bytes undercut the literal bytes
        if m >= MIN_MATCH and m * width > 3:
            flush(lit, i)
            out.append(128 + (m - MIN_MATCH))
            out.extend(dist.to_bytes(2, "little"))
            for j in range(i + 1, min(i + 4, i + m, n - MIN_MATCH + 1)):
                head[(vals[j], vals[j + 1])] = j
            i += m
            lit = i
        else:
            i += 1
    flush(lit, n)
    return bytes(out)


def compress_lzss(arr: np.ndarray,
                  chunk_bytes: int = fmt.DEFAULT_CHUNK_BYTES,
                  bits: int | None = None) -> fmt.CompressedBlob:
    chunks, chunk_elems, width, _ = fmt.chunk_array(arr, chunk_bytes)
    encoded = [encode_lzss_chunk(c, width) for c in chunks]
    return fmt.build_blob(LZSS, arr, encoded, chunk_elems, width)


# --------------------------------------------------------------------------
# decode bodies
# --------------------------------------------------------------------------


def _body(inputs, consts, out_len, *, chunk_elems, width, bits, dbl_unroll=1):
    (comp,) = inputs
    dt = harness.DEV_DTYPE[width]
    MT = max_tokens(chunk_elems)

    # ---- Phase 1: sequential token parse -> group tables ------------------
    def cond(s):
        return jnp.logical_and(s[2] < out_len, s[1] < MT)

    def body1(s):
        pos, g, cnt, starts, kinds, dists, litoffs = s
        c = st.read_byte_at(comp, pos)
        is_m = c >= 128
        length = jnp.where(is_m, c - 128 + MIN_MATCH, c + 1)
        dist = st.read_value_at(comp, pos + 1, 2).astype(jnp.int32)
        starts = starts.at[g].set(cnt)
        kinds = kinds.at[g].set(is_m)
        dists = dists.at[g].set(dist)
        litoffs = litoffs.at[g].set(pos + 1)
        adv = jnp.where(is_m, 3, 1 + length * width)
        return pos + adv, g + 1, cnt + length, starts, kinds, dists, litoffs

    init = (jnp.int32(0), jnp.int32(0), jnp.int32(0),
            jnp.full((MT,), chunk_elems, jnp.int32),   # sentinel = chunk_elems
            jnp.zeros((MT,), jnp.bool_),
            jnp.zeros((MT,), jnp.int32),
            jnp.zeros((MT,), jnp.int32))
    _, _, _, starts, kinds, dists, litoffs = lax.while_loop(cond, body1, init)

    # ---- Phase 2: all-thread copy resolution ------------------------------
    marker = jnp.zeros((chunk_elems + 1,), jnp.int32).at[starts].add(1)
    grp = jnp.cumsum(marker[:chunk_elems]) - 1
    idx = jnp.arange(chunk_elems, dtype=jnp.int32)
    k = idx - jnp.take(starts, grp, mode="clip")
    is_m = jnp.take(kinds, grp, mode="clip")
    dist = jnp.take(dists, grp, mode="clip")
    litbyte = jnp.take(litoffs, grp, mode="clip") + k * width

    # literal lanes are fixed points; match lanes point dist elements back.
    # Chains strictly decrease, so log2 pointer-doubling rounds resolve
    # every lane to its terminal literal lane (extra rounds are idempotent).
    ptr = jnp.where(is_m, jnp.maximum(idx - dist, 0), idx)
    rounds = max(1, (chunk_elems - 1).bit_length())

    def dbl(r, p):
        for _ in range(dbl_unroll):   # static unroll inside one loop step
            p = jnp.take(p, p, mode="clip")
        return p

    ptr = lax.fori_loop(0, -(-rounds // dbl_unroll), dbl, ptr)
    vals = st.gather_values(comp, jnp.take(litbyte, ptr, mode="clip"), width)
    return jnp.where(idx < out_len, vals, 0).astype(dt)


def _body_scalar(inputs, consts, out_len, *, chunk_elems, width, bits):
    """§V-E single-thread baseline: one element per step; matches proceed
    element-by-element through a scalar back-reference cursor."""
    (comp,) = inputs
    dt = harness.DEV_DTYPE[width]

    def cond(s):
        return s[1] < out_len

    def body(s):
        pos, i, rem, is_m, src, buf = s
        need = rem == 0
        c = st.read_byte_at(comp, pos)
        new_m = c >= 128
        new_len = jnp.where(new_m, c - 128 + MIN_MATCH, c + 1)
        new_dist = st.read_value_at(comp, pos + 1, 2).astype(jnp.int32)
        # src is an element index for matches, a byte offset for literals
        new_src = jnp.where(new_m, i - new_dist, pos + 1)
        is_m = jnp.where(need, new_m, is_m)
        rem = jnp.where(need, new_len, rem)
        src = jnp.where(need, new_src, src)
        pos = jnp.where(need,
                        pos + jnp.where(new_m, 3, 1 + new_len * width), pos)
        v_lit = st.gather_values(comp, src, width)
        v_m = jnp.take(buf, jnp.maximum(src, 0), mode="clip").astype(jnp.uint32)
        buf = buf.at[i].set(jnp.where(is_m, v_m, v_lit).astype(dt))
        return (pos, i + 1, rem - 1, is_m,
                src + jnp.where(is_m, 1, width), buf)

    init = (jnp.int32(0), jnp.int32(0), jnp.int32(0), jnp.bool_(False),
            jnp.int32(0), jnp.zeros((chunk_elems,), dt))
    s = lax.while_loop(cond, body, init)
    return s[5]


def _body_oracle(inputs, consts, out_len, *, chunk_elems, width, bits):
    """Serial token walk with the Table II primitives: blend-write literal
    runs, overlap-safe circular-window ``memcpy`` for matches."""
    (comp,) = inputs
    dt = harness.DEV_DTYPE[width]
    lanes = jnp.arange(CW, dtype=jnp.int32)

    def cond(s):
        return s[1].pos < out_len

    def body(s):
        pos, out = s
        c = st.read_byte_at(comp, pos)
        is_m = c >= 128
        length = jnp.where(is_m, c - 128 + MIN_MATCH, c + 1)
        dist = st.read_value_at(comp, pos + 1, 2).astype(jnp.int32)
        out_m = st.memcpy(out, dist, length, CW)
        lit_vals = st.gather_values(comp, pos + 1 + lanes * width,
                                    width).astype(dt)
        cur = lax.dynamic_slice(out.buf, (out.pos,), (CW,))
        new = jnp.where(lanes < length, lit_vals, cur)
        out_l = out._replace(
            buf=lax.dynamic_update_slice(out.buf, new, (out.pos,)),
            pos=out.pos + length)
        out = jax.tree.map(lambda a, b: jnp.where(is_m, a, b), out_m, out_l)
        return pos + jnp.where(is_m, 3, 1 + length * width), out

    _, out = lax.while_loop(
        cond, body, (jnp.int32(0), st.outstream(chunk_elems + CW, dt)))
    idx = jnp.arange(chunk_elems, dtype=jnp.int32)
    return jnp.where(idx < out_len, out.buf[:chunk_elems], 0)


def _pallas(body, inputs, consts, out_lens, *, chunk_elems, width, bits,
            interpret, tune=()):
    """Generic wrapper with the ``dbl_unroll`` knob baked into the body
    (how many pointer-doubling gathers fuse into one loop step)."""
    unroll = int(dict(tune).get("dbl_unroll", 1))
    tuned = functools.partial(_body, dbl_unroll=unroll)
    return harness._generic_pallas(tuned, inputs, consts, out_lens,
                                   chunk_elems=chunk_elems, width=width,
                                   bits=bits, interpret=interpret, tune=tune)


# --------------------------------------------------------------------------
# registry plumbing
# --------------------------------------------------------------------------


def _count_groups(row, width: int) -> int:
    pos, n, groups = 0, len(row), 0
    while pos < n:
        c = int(row[pos])
        pos += 3 if c >= 128 else 1 + (c + 1) * width
        groups += 1
    return groups


def _demo_data(n: int, rng) -> np.ndarray:
    """Repeating element motifs + sparse noise (LZ's bread and butter)."""
    motif = rng.integers(0, 1 << 12, 48).astype(np.uint32)
    out = np.tile(motif, n // motif.size + 1)[:n].copy()
    noise = rng.random(n) < 0.04
    out[noise] = rng.integers(0, 1 << 12, int(noise.sum()))
    return out


CODEC = registry.register(registry.Codec(
    name=LZSS,
    encode=compress_lzss,
    decode=harness.DecodeSpec(
        body=_body,
        body_scalar=_body_scalar,
        body_oracle=_body_oracle,
        pallas_override=_pallas,
        tunables=(harness.Tunable("dbl_unroll", (1, 2, 4), 1),),
    ),
    plane_decompose_64=True,
    demo_data=_demo_data,
    count_groups=_count_groups,
))
