"""Parallel canonical Huffman over bytes — the gap-array codec.

A pure entropy stream is the worst case for CODAG's two-phase split: symbol
boundaries are only known after decoding, so Phase 2 cannot jump to element
``k`` of a group.  The fix (Rivera et al.'s gap array, arXiv 2201.09118) is
an encoder-side index: the stream is cut into fixed-size *segments* of
``SUB`` symbols, and a per-segment gap entry (bit offset + count) lets every
segment decode independently:

  Phase 1 is trivially parallel here — gap entries are fixed-size, so the
      per-segment tables are a vectorized gather, not a leader loop.
  Phase 2 (lockstep expansion): seed one bit cursor per segment from its
      gap entry, then step ALL segments together — every step peeks
      ``MAX_CODE_BITS`` LSB-first bits per cursor lane (one vectorized
      funnel-shift load), resolves (symbol, code length) through the
      chunk's flat canonical-decode LUT, writes the symbol column, and
      advances each cursor by its own code length.  ``SUB`` steps decode
      the whole chunk with n_segments-way parallelism.

Chunk layout:

  [gap table: n_segments x 5 bytes] [Huffman payload, LSB-first bits]
  gap entry g: bytes 0..3 = u32 LE absolute bit offset of segment g's
  first symbol (relative to the chunk row start, gap table included);
  byte 4 = symbol count - 1 (1..SUB symbols).

``n_segments`` is recoverable from the stream alone: entry 0's bit offset
is the gap table's own size in bits, so ``offset0 / 40`` counts segments
(what ``count_groups`` reports for Table V symbol lengths).

The per-chunk canonical code is carried as ``hdr_hlens`` (256 code lengths,
the only table a real container would ship — counted in ``ratio``); the
flat 4096-entry decode LUTs (``lut_hsym`` / ``lut_hbits``) are derived from
it at encode time and ride the device pytree like tdeflate's.

Backends cross-check the index from two directions: the scalar §V-E body
deliberately IGNORES the gap array (beyond entry 0) and decodes the payload
as one sequential bit stream — the encoder's segment offsets must agree
with the payload bit-for-bit or the suites fail; the oracle walks segment
by segment trusting both the offsets and the count bytes.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import encoders as enc
from repro.core import format as fmt
from repro.core import registry
from repro.core import streams as st
from repro.kernels import harness

HUFFMAN = "huffman"

SUB = 32                 # symbols per self-synchronizing segment
GAP_ENTRY_BYTES = 5      # u32 LE bit offset + (count - 1) byte


# --------------------------------------------------------------------------
# host encoder (vectorized: one np scatter packs the whole chunk)
# --------------------------------------------------------------------------


def _pack_lsb(vals: np.ndarray, nbits: np.ndarray) -> Tuple[bytes, np.ndarray]:
    """Pack variable-width fields LSB-first. Returns (payload, start bits).

    Same disjoint-bit-field scatter as ``encoders.pack_bits``, generalized
    to per-field widths: field bit ranges never overlap, so scatter-add is
    scatter-or and each uint64 accumulator word stays below 2^43.
    """
    nbits = nbits.astype(np.int64)
    ends = np.cumsum(nbits)
    starts = ends - nbits
    total = int(ends[-1]) if ends.size else 0
    nwords = (total + 31) // 32
    acc = np.zeros(nwords + 2, np.uint64)
    v = vals.astype(np.uint64)
    word = (starts >> 5).astype(np.int64)
    off = (starts & 31).astype(np.uint64)
    np.add.at(acc, word, (v << off) & np.uint64(0xFFFFFFFF))
    np.add.at(acc, word + 1, np.where(off > 0, v >> (np.uint64(32) - off),
                                      np.uint64(0)))
    payload = acc[:nwords].astype(np.uint32).tobytes()[: (total + 7) // 8]
    return payload, starts


def encode_huffman_chunk(data: np.ndarray) -> Tuple[bytes, np.ndarray]:
    """Encode one uint8 chunk. Returns (gap table + payload, code lengths)."""
    data = np.ascontiguousarray(data).view(np.uint8)
    lens = enc.limited_huffman_lengths(
        np.bincount(data, minlength=256).astype(np.int64), enc.MAX_CODE_BITS)
    n = data.shape[0]
    if n == 0:
        return b"", lens.astype(np.uint8)
    codes = enc.canonical_codes(lens)
    # pre-reversed for LSB-first emission, indexed by byte value
    rev = np.array([enc._bit_reverse(int(codes[s]), int(lens[s]))
                    for s in range(256)], np.uint64)
    payload, starts = _pack_lsb(rev[data], lens[data])
    nseg = (n + SUB - 1) // SUB
    gap_bits = nseg * GAP_ENTRY_BYTES * 8
    head = np.empty((nseg, GAP_ENTRY_BYTES), np.uint8)
    head[:, :4] = (gap_bits + starts[::SUB]).astype("<u4") \
        .view(np.uint8).reshape(nseg, 4)
    head[:, 4] = (np.minimum(SUB, n - np.arange(nseg) * SUB) - 1).astype(np.uint8)
    return head.tobytes() + payload, lens.astype(np.uint8)


def compress_huffman(arr: np.ndarray,
                     chunk_bytes: int = fmt.DEFAULT_CHUNK_BYTES,
                     bits: int | None = None) -> fmt.CompressedBlob:
    chunks, chunk_elems, width, _ = fmt.chunk_array(arr, chunk_bytes)
    # byte codec: re-chunk at byte granularity (like tdeflate)
    chunks = [np.ascontiguousarray(c).view(np.uint8) for c in chunks]
    payloads, hlens, lut_s, lut_b = [], [], [], []
    for c in chunks:
        p, hl = encode_huffman_chunk(c)
        payloads.append(p)
        hlens.append(hl)
        s, b = enc.build_decode_lut(hl.astype(np.int32))
        lut_s.append(s)
        lut_b.append(b)
    extras = {
        "hdr_hlens": np.stack(hlens),
        "lut_hsym": np.stack(lut_s),
        "lut_hbits": np.stack(lut_b),
    }
    total_bytes = sum(int(c.shape[0]) for c in chunks)
    return fmt.build_blob(HUFFMAN, arr, payloads, chunk_elems * width, 1,
                          extras, total_elems=total_bytes)


# --------------------------------------------------------------------------
# decode bodies
# --------------------------------------------------------------------------


def _decode_lockstep(comp, words, lut_sym, lut_bits, out_len,
                     chunk_elems: int, unroll: int = 1) -> jnp.ndarray:
    """All segments decode in lockstep: one bit cursor per segment, SUB
    steps, each a vectorized peek/LUT/advance across every cursor lane."""
    nseg = (chunk_elems + SUB - 1) // SUB
    segs = jnp.arange(nseg, dtype=jnp.int32)
    bitpos = st.gather_values(comp, segs * GAP_ENTRY_BYTES, 4).astype(jnp.int32)

    def one(t, bitpos, out):
        v = st.peek_bits(st.BitStream(words=words, pos=bitpos),
                         enc.MAX_CODE_BITS)
        sym = jnp.take(lut_sym, v.astype(jnp.int32), mode="clip")
        nb = jnp.take(lut_bits, v.astype(jnp.int32), mode="clip")
        return bitpos + nb, out.at[:, t].set(sym.astype(jnp.uint32))

    def step(i, carry):
        bitpos, out = carry
        for u in range(unroll):     # static unroll inside one loop step
            bitpos, out = one(i * unroll + u, bitpos, out)
        return bitpos, out

    _, out = lax.fori_loop(0, SUB // unroll, step,
                           (bitpos, jnp.zeros((nseg, SUB), jnp.uint32)))
    flat = out.reshape(-1)[:chunk_elems]
    idx = jnp.arange(chunk_elems, dtype=jnp.int32)
    return jnp.where(idx < out_len, flat, 0)


def _body(inputs, consts, out_len, *, chunk_elems, width, bits, sub_unroll=1):
    comp, words, lut_sym, lut_bits = inputs
    out = _decode_lockstep(comp, words, lut_sym, lut_bits, out_len,
                           chunk_elems, unroll=sub_unroll)
    return out.astype(harness.DEV_DTYPE[width])


def _body_scalar(inputs, consts, out_len, *, chunk_elems, width, bits):
    """§V-E single-thread baseline: one symbol per step, sequentially from
    the payload start — the gap array (beyond entry 0) is deliberately
    unused, so this body cross-checks the encoder's segment offsets."""
    comp, words, lut_sym, lut_bits = inputs
    dt = harness.DEV_DTYPE[width]
    pos0 = st.read_value_at(comp, 0, 4).astype(jnp.int32)   # = gap table bits

    def cond(s):
        return s[1] < out_len

    def body(s):
        pos, i, buf = s
        v = st.peek_bits(st.BitStream(words=words, pos=pos), enc.MAX_CODE_BITS)
        sym = jnp.take(lut_sym, v.astype(jnp.int32), mode="clip")
        nb = jnp.take(lut_bits, v.astype(jnp.int32), mode="clip")
        return pos + nb, i + 1, buf.at[i].set(sym.astype(dt))

    s = lax.while_loop(cond, body, (pos0, jnp.int32(0),
                                    jnp.zeros((chunk_elems,), dt)))
    return s[2]


def _body_oracle(inputs, consts, out_len, *, chunk_elems, width, bits):
    """Sequential reference: segment by segment through the gap table, each
    segment decoded serially from its own bit offset and blend-written at
    the running count — validates offsets AND count bytes."""
    comp, words, lut_sym, lut_bits = inputs
    dt = harness.DEV_DTYPE[width]
    lanes = jnp.arange(SUB, dtype=jnp.int32)

    def cond(s):
        return s[1] < out_len

    def body(s):
        g, cnt, buf = s
        bitoff = st.read_value_at(comp, g * GAP_ENTRY_BYTES, 4).astype(jnp.int32)
        count = st.read_byte_at(comp, g * GAP_ENTRY_BYTES + 4) + 1

        def inner(t, c):
            pos, vals = c
            v = st.peek_bits(st.BitStream(words=words, pos=pos),
                             enc.MAX_CODE_BITS)
            sym = jnp.take(lut_sym, v.astype(jnp.int32), mode="clip")
            nb = jnp.take(lut_bits, v.astype(jnp.int32), mode="clip")
            return pos + nb, vals.at[t].set(sym.astype(dt))

        _, vals = lax.fori_loop(0, SUB, inner,
                                (bitoff, jnp.zeros((SUB,), dt)))
        cur = lax.dynamic_slice(buf, (cnt,), (SUB,))
        new = jnp.where(lanes < count, vals, cur)
        return (g + 1, cnt + count,
                lax.dynamic_update_slice(buf, new, (cnt,)))

    _, _, buf = lax.while_loop(
        cond, body,
        (jnp.int32(0), jnp.int32(0), jnp.zeros((chunk_elems + SUB,), dt)))
    return buf[:chunk_elems]


def _pallas(body, inputs, consts, out_lens, *, chunk_elems, width, bits,
            interpret, tune=()):
    """Generic wrapper with the codec's ``sub_unroll`` knob baked into the
    lockstep body (plain bodies never see ``tune``; the override does)."""
    unroll = int(dict(tune).get("sub_unroll", 1))
    tuned = functools.partial(_body, sub_unroll=unroll)
    return harness._generic_pallas(tuned, inputs, consts, out_lens,
                                   chunk_elems=chunk_elems, width=width,
                                   bits=bits, interpret=interpret, tune=tune)


# --------------------------------------------------------------------------
# registry plumbing
# --------------------------------------------------------------------------


def _chunk_inputs(dev):
    """Per-chunk operands: raw bytes (gap table), word view (payload bits),
    and the two flat canonical-decode LUTs."""
    words = dev.get("comp_words")
    if words is None:
        words = harness.words_view(dev["comp"])
    return (dev["comp"], words,
            dev["lut_hsym"].astype(jnp.int32),
            dev["lut_hbits"].astype(jnp.int32))


def _count_groups(row, width: int) -> int:
    if len(row) < GAP_ENTRY_BYTES:
        return 0
    # entry 0's bit offset == the gap table's own size in bits
    off0 = int.from_bytes(bytes(bytearray(row[:4])), "little")
    return off0 // (GAP_ENTRY_BYTES * 8)


def _demo_data(n: int, rng) -> np.ndarray:
    """Geometrically skewed bytes — the entropy coder's natural habitat."""
    return np.minimum(rng.geometric(0.25, n) - 1, 255).astype(np.uint8)


CODEC = registry.register(registry.Codec(
    name=HUFFMAN,
    encode=compress_huffman,
    decode=harness.DecodeSpec(
        body=_body,
        body_scalar=_body_scalar,
        body_oracle=_body_oracle,
        chunk_inputs=_chunk_inputs,
        pallas_override=_pallas,
        tunables=(harness.Tunable("sub_unroll", (1, 2, 4), 1),),
    ),
    needs_words=True,
    byte_stream=True,
    demo_data=_demo_data,
    count_groups=_count_groups,
))
