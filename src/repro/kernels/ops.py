"""jit'd dispatch layer over the decompression kernels.

Backends:
  "xla"    — the two-phase decode bodies vmapped across chunks and compiled
             by XLA (used on CPU and as the production non-Pallas path).
  "pallas" — pl.pallas_call kernels (interpret=True on CPU for validation,
             interpret=False on real TPU).
  "oracle" — the sequential stream-based reference decoders (kernels/ref.py).
  "scalar" — the single-thread-decoding §V-E ablation baselines.

All entry points take the device pytree from ``CompressedBlob.to_device()``
plus the blob's static metadata, and return (num_chunks, chunk_elems).
"""
from __future__ import annotations

import contextlib
import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import format as fmt
from repro.kernels import bitpack, ref, rle_v1, rle_v2, tdeflate

BACKENDS = ("xla", "pallas", "oracle", "scalar")


def words_view(comp: jnp.ndarray) -> jnp.ndarray:
    """(n, C) uint8 -> (n, C//4) uint32 little-endian word view."""
    n, c = comp.shape
    b = comp.reshape(n, c // 4, 4).astype(jnp.uint32)
    return (b[..., 0] | (b[..., 1] << 8) | (b[..., 2] << 16) | (b[..., 3] << 24))


@functools.partial(jax.jit, static_argnames=("codec", "width", "chunk_elems",
                                             "backend", "interpret", "bits"))
def decode(dev: Dict[str, Any], *, codec: str, width: int, chunk_elems: int,
           backend: str = "xla", interpret: bool = True,
           bits: int = 0) -> jnp.ndarray:
    """Decode every chunk. Returns (num_chunks, chunk_elems) device array."""
    comp = dev["comp"]
    out_lens = dev["out_lens"]

    if codec == fmt.RLE_V1:
        if backend == "pallas":
            return rle_v1.decode_pallas(comp, out_lens, width=width,
                                        chunk_elems=chunk_elems,
                                        interpret=interpret)
        body = {"xla": rle_v1.decode_chunk,
                "scalar": rle_v1.decode_chunk_scalar,
                "oracle": ref.decode_rle_v1_impl}[backend]
        return jax.vmap(lambda c, n: body(c, n, chunk_elems, width))(comp, out_lens)

    if codec == fmt.RLE_V2:
        if backend == "pallas":
            return rle_v2.decode_pallas(comp, out_lens, width=width,
                                        chunk_elems=chunk_elems,
                                        interpret=interpret)
        body = {"xla": rle_v2.decode_chunk,
                "scalar": rle_v2.decode_chunk_scalar,
                "oracle": ref.decode_rle_v2_impl}[backend]
        return jax.vmap(lambda c, n: body(c, n, chunk_elems, width))(comp, out_lens)

    if codec == fmt.TDEFLATE:
        words = dev.get("comp_words")
        if words is None:
            words = words_view(comp)
        luts = tuple(dev[k].astype(jnp.int32) for k in
                     ("lut_lsym", "lut_lbits", "lut_dsym", "lut_dbits"))
        if backend == "pallas":
            return tdeflate.decode_pallas(words, luts, out_lens,
                                          chunk_bytes=chunk_elems,
                                          interpret=interpret)
        body = {"xla": tdeflate.decode_chunk,
                "scalar": tdeflate.decode_chunk_scalar,
                "oracle": ref.decode_tdeflate_impl}[backend]
        return jax.vmap(
            lambda w_, a, b, c, d, n: body(w_, a, b, c, d, n, chunk_elems)
        )(words, *luts, out_lens)

    if codec == fmt.BITPACK:
        words = dev.get("comp_words")
        if words is None:
            words = words_view(comp)
        if backend == "pallas":
            return bitpack.unpack_pallas(words, bits=bits,
                                         out_elems=chunk_elems,
                                         interpret=interpret)
        return jax.vmap(
            lambda w_: bitpack.unpack_tile(w_, jnp.int32(0), chunk_elems, bits)
        )(words)

    raise ValueError(f"unknown codec {codec}")


@contextlib.contextmanager
def count_dispatches():
    """Observe python-level ``decode`` dispatches (= kernel launches issued).

    Yields a list that grows one entry per call, with the static decode
    kwargs plus the table's chunk count.  Every caller (engine, batch
    scheduler, tests, benchmarks) resolves ``ops.decode`` through the module
    attribute at call time, so rebinding it here observes them all.
    """
    calls: list = []
    orig = decode

    def counting(dev, **kw):
        calls.append({"num_chunks": int(dev["comp"].shape[0]), **kw})
        return orig(dev, **kw)

    globals()["decode"] = counting
    try:
        yield calls
    finally:
        globals()["decode"] = orig


def table_inputs(table: fmt.CompressedBlob):
    """(device pytree, static bitpack bits) for a blob / merged chunk table."""
    dev = {k: jnp.asarray(v) for k, v in table.to_device().items()}
    bits = (int(table.extras["bitpack_bits"][0])
            if table.codec == fmt.BITPACK else 0)
    return dev, bits


def cast_table_output(table: fmt.CompressedBlob, out) -> np.ndarray:
    """Bring a decode result to host in the table's element dtype."""
    out = np.asarray(out)
    if table.codec == fmt.BITPACK:
        out = out.astype({1: np.uint8, 2: np.uint16, 4: np.uint32}[table.width])
    return out


def decode_table(table: fmt.CompressedBlob, backend: str = "xla",
                 interpret: bool = True) -> np.ndarray:
    """Decode a flat chunk table with ONE dispatch, no reassembly.

    ``table`` may be a single blob or a multi-blob merge from
    ``format.concat_blobs`` (the batch scheduler's stream table): every row
    is an independent stream regardless of which blob it came from.  Returns
    the raw (num_chunks, chunk_elems) matrix in the blob's element dtype;
    callers that own a blob→row mapping scatter it back themselves.
    """
    dev, bits = table_inputs(table)
    out = decode(dev, codec=table.codec, width=table.width,
                 chunk_elems=table.chunk_elems, backend=backend,
                 interpret=interpret, bits=bits)
    return cast_table_output(table, out)


def decode_blob(blob: fmt.CompressedBlob, backend: str = "xla",
                interpret: bool = True) -> np.ndarray:
    """Host convenience: decode a CompressedBlob back to the original array."""
    return fmt.reassemble(blob, decode_table(blob, backend, interpret))
