"""jit'd dispatch layer over the decompression kernels.

Backends:
  "xla"    — the per-codec chunk bodies vmapped across chunks and compiled
             by XLA (used on CPU and as the production non-Pallas path).
  "pallas" — pl.pallas_call kernels (interpret=True on CPU for validation,
             interpret=False on real TPU).
  "oracle" — the sequential stream-based reference decoders.
  "scalar" — the single-thread-decoding §V-E ablation baselines.

Dispatch is pure registry lookup: ``registry.get(codec).decode`` is a
``kernels.harness.DecodeSpec`` carrying all four backend bodies, so this
module names no codec.  All entry points take the device pytree from
``CompressedBlob.to_device()`` plus the blob's static metadata, and return
(num_chunks, chunk_elems) in the codec's device dtype.
"""
from __future__ import annotations

import contextlib
import functools
import threading
from typing import Any, Dict

import jax

from repro.core import format as fmt
from repro.core import registry
from repro.core import transfers
from repro.kernels import harness
from repro.kernels.harness import Epilogue  # noqa: F401  (public alias)
from repro.kernels.harness import words_view  # noqa: F401  (public alias)

BACKENDS = ("xla", "pallas", "oracle", "scalar")


@functools.partial(jax.jit, static_argnames=("codec", "width", "chunk_elems",
                                             "backend", "interpret", "bits",
                                             "epilogue", "tune"))
def _decode_impl(dev: Dict[str, Any], *, codec: str, width: int,
                 chunk_elems: int, backend: str, interpret: bool,
                 bits: int, epilogue, tune) -> jax.Array:
    return harness.run(registry.get(codec).decode, dev, width=width,
                       chunk_elems=chunk_elems, backend=backend,
                       interpret=interpret, bits=bits, epilogue=epilogue,
                       tune=tune)


# Dispatch observers (``count_dispatches``).  A plain list-of-lists instead
# of rebinding the module attribute: nested/overlapping contexts each get
# every dispatch, and exiting one never clobbers another.  Dispatches may be
# issued from worker threads (the DecompressionService), so registration,
# unregistration, and the record fan-out all happen under one lock.
_observers: list = []
_observers_lock = threading.Lock()


def decode(dev: Dict[str, Any], *, codec: str, width: int, chunk_elems: int,
           backend: str = "xla", interpret: bool = True, bits: int = 0,
           epilogue=None, tune=None) -> jax.Array:
    """Decode every chunk. Returns (num_chunks, chunk_elems) device array.

    ``epilogue``: optional ``harness.Epilogue`` fused into the dispatch
    (cast / widen / dequant applied before the matrix ever exists for the
    consumer); overrides the codec's registered default epilogue.

    ``tune``: sorted kernel-knob tuple (jit-static; see ``core.tuning``).
    ``None`` resolves the tuned defaults for ``(codec, width)`` on the
    current device — callers that trace this function inside an outer jit
    (the plan executors) must resolve and pass it explicitly instead, so a
    swapped tuning table can never silently reuse a stale compilation.
    """
    if tune is None:
        from repro.core import tuning
        tune = tuning.kernel_tune(codec, width)
    # Observer fan-out happens entirely under the lock: the old pattern
    # (truthiness check outside, iteration inside) was a TOCTOU — a context
    # registered between check and fan-out saw a dispatch-count of zero for
    # a dispatch issued strictly inside it, and one unregistered in that
    # window could still be appended to after its context closed.
    with _observers_lock:
        if _observers:
            rec = {"num_chunks": int(dev["comp"].shape[0]), "codec": codec,
                   "width": width, "chunk_elems": chunk_elems,
                   "backend": backend, "interpret": interpret, "bits": bits}
            for calls in _observers:
                calls.append(dict(rec))
    return _decode_impl(dev, codec=codec, width=width,
                        chunk_elems=chunk_elems, backend=backend,
                        interpret=interpret, bits=bits, epilogue=epilogue,
                        tune=tune)


@contextlib.contextmanager
def count_dispatches():
    """Observe python-level ``decode`` dispatches (= kernel launches issued).

    Yields a list that grows one entry per call, with the static decode
    kwargs plus the table's chunk count.  Reentrant: contexts may nest or
    overlap arbitrarily — each active context records every dispatch issued
    while it is open, and closing one leaves the others intact.
    """
    calls: list = []
    with _observers_lock:
        _observers.append(calls)
    try:
        yield calls
    finally:
        # remove by identity: two open contexts may hold equal-valued lists
        with _observers_lock:
            for i, obs in enumerate(_observers):
                if obs is calls:
                    del _observers[i]
                    break


def table_inputs(table: fmt.CompressedBlob, placement=None):
    """(device pytree, static decode bits) for a blob / merged chunk table.

    ``placement``: optional ``jax.Device`` / ``jax.sharding.Sharding`` the
    staged tables should live under (multi-device schedulers stage per
    device).  All uploads go through the ``transfers.to_device`` funnel so
    staging traffic is countable."""
    dev = {k: transfers.to_device(v, placement)
           for k, v in table.to_device().items()}
    return dev, registry.get(table.codec).static_bits(table)


def decode_table_device(table: fmt.CompressedBlob, backend: str = "xla",
                        interpret: bool = True, epilogue=None) -> jax.Array:
    """Decode a flat chunk table with ONE dispatch; result stays on device.

    ``table`` may be a single blob or a multi-blob merge from
    ``format.concat_blobs`` (the batch scheduler's stream table): every row
    is an independent stream regardless of which blob it came from.  Returns
    the raw (num_chunks, chunk_elems) device matrix; callers that own a
    blob→row mapping scatter it back themselves
    (``format.reassemble_device``).
    """
    dev, bits = table_inputs(table)
    return decode(dev, codec=table.codec, width=table.width,
                  chunk_elems=table.chunk_elems, backend=backend,
                  interpret=interpret, bits=bits, epilogue=epilogue)


def decode_table(table: fmt.CompressedBlob, backend: str = "xla",
                 interpret: bool = True):
    """Host variant of :func:`decode_table_device`: one dispatch, then one
    sanctioned device→host materialization (``transfers.to_host``)."""
    return transfers.to_host(decode_table_device(table, backend, interpret))


def decode_blob(blob: fmt.CompressedBlob, backend: str = "xla",
                interpret: bool = True):
    """Host convenience: decode a CompressedBlob back to the original array."""
    return fmt.reassemble(blob, decode_table(blob, backend, interpret))
