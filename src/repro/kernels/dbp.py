"""dbp codec plugin — frame-of-reference delta + bitpack (NEW codec).

The extensibility proof for the codec-plugin framework: everything dbp
needs — encoder, all four decode backends, batch-scheduler grouping,
checkpoint restore, pipeline decode, bench/test matrices — comes from this
one module plus its ``registry.register`` call.  Nothing outside the plugin
names the codec.

Format (ORC RLE v2 direct-mode spirit; the natural encoding for token ids,
timestamps, sorted ids, and quantized optimizer state): the chunk is split
into groups of up to 256 elements; each group stores its minimum (the frame
of reference) and LSB-first bitpacks every element's offset from it.

Per-group byte-aligned layout:
  byte 0            bit width b (0..32; 0 = all elements equal the ref)
  byte 1            count-1 (group length 1..256)
  bytes 2..2+w-1    ref, little-endian, w = element width
  payload           ceil(count*b/8) bytes, LSB-first packed (val - ref)

Phase 1 parses that fixed-shape header (trivially sequential: the payload
length depends on b and count).  Phase 2 is pure all-thread: every lane
funnel-shifts its own b-bit field out of the payload and adds the ref — the
same position-independence that makes plain bitpack the paper's best case,
but with per-group references so unsorted-but-local data still compresses.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import encoders as enc
from repro.core import format as fmt
from repro.core import registry
from repro.core import streams as st
from repro.kernels import harness

GROUP = 128            # encoder group size (any count in 1..256 decodes)
MAX_GROUP_LEN = 256


def max_groups(out_len: int) -> int:
    return out_len + 4   # any stream of >=1-element groups is decodable


# --------------------------------------------------------------------------
# host encoder
# --------------------------------------------------------------------------


def encode_dbp_chunk(x: np.ndarray, width: int) -> bytes:
    """Encode one chunk: per-group (bits, count-1, ref, packed offsets)."""
    out = bytearray()
    xs = np.ascontiguousarray(x).astype(np.uint32)
    for i in range(0, xs.shape[0], GROUP):
        g = xs[i:i + GROUP]
        ref_v = int(g.min())
        deltas = (g - np.uint32(ref_v)).astype(np.uint64)
        bits = int(deltas.max()).bit_length()
        out.append(bits)
        out.append(len(g) - 1)
        out.extend(int(ref_v).to_bytes(4, "little")[:width])
        if bits:
            payload = enc.pack_bits(deltas, bits).tobytes()
            out.extend(payload[: (len(g) * bits + 7) // 8])
    return bytes(out)


def compress_dbp(arr: np.ndarray, chunk_bytes: int = fmt.DEFAULT_CHUNK_BYTES,
                 bits=None) -> fmt.CompressedBlob:
    """Host encoder entry point (``bits`` is unused: widths are per-group)."""
    chunks, chunk_elems, width, _ = fmt.chunk_array(arr, chunk_bytes)
    encoded = [encode_dbp_chunk(c, width) for c in chunks]
    return fmt.build_blob(fmt.DBP, arr, encoded, chunk_elems, width)


# --------------------------------------------------------------------------
# decode: header parse + value expression (the whole kernel)
# --------------------------------------------------------------------------


def _parse(comp, pos, width: int):
    bits = st.read_byte_at(comp, pos)
    count = st.read_byte_at(comp, pos + 1) + 1
    return {
        "length": count,
        "advance": 2 + width + ((count * bits + 7) >> 3),
        "ref": st.read_value_at(comp, pos + 2, width),
        "bits": bits,
        "payoff": pos + 2 + width,
    }


def _express(comp, f, k, width: int):
    """Lane k funnel-shifts its b-bit offset from the payload, adds ref.

    The 40-bit window (an unaligned uint32 + one spill byte) covers any
    b <= 32 at any intra-byte offset 0..7.
    """
    bits = f["bits"]
    bitpos = f["payoff"] * 8 + k * bits
    byte = bitpos >> 3
    off = (bitpos & 7).astype(jnp.uint32)
    w0 = st.gather_values(comp, byte, 4)
    b4 = jnp.take(comp, byte + 4, mode="clip").astype(jnp.uint32)
    lo = jnp.right_shift(w0, off)
    hi = jnp.where(off > 0,
                   jnp.left_shift(b4, (jnp.uint32(32) - off) & jnp.uint32(31)),
                   jnp.uint32(0))
    # dynamic-width mask; shift amount capped at 31 to stay well-defined
    nb = jnp.minimum(bits, 31).astype(jnp.uint32)
    mask = jnp.where(bits >= 32, jnp.uint32(0xFFFFFFFF),
                     (jnp.uint32(1) << nb) - jnp.uint32(1))
    return f["ref"] + ((lo | hi) & mask)


SPEC = harness.TwoPhaseSpec(
    fields=(harness.Field("ref", jnp.uint32),
            harness.Field("bits", jnp.int32),
            harness.Field("payoff", jnp.int32)),
    parse=_parse,
    express=_express,
    max_groups=max_groups,
    max_group_len=MAX_GROUP_LEN,
)


def _count_groups(row, width: int) -> int:
    pos, groups = 0, 0
    while pos < len(row):
        bits, count = int(row[pos]), int(row[pos + 1]) + 1
        pos += 2 + width + (count * bits + 7) // 8
        groups += 1
    return groups


def _demo_data(n: int, rng) -> np.ndarray:
    """Sorted-id / timestamp-like uint32s: small per-group value ranges."""
    return np.cumsum(rng.integers(0, 16, n)).astype(np.uint32)


CODEC = registry.register(registry.Codec(
    name=fmt.DBP,
    encode=compress_dbp,
    # oracle defaults to the harness's generic group-serial driver — a new
    # codec gets a paper-faithful sequential reference for free.
    decode=harness.DecodeSpec.from_two_phase(SPEC),
    plane_decompose_64=True,
    demo_data=_demo_data,
    count_groups=_count_groups,
))
