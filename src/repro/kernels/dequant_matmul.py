"""Fused int8-dequant matmul — Pallas TPU kernel + device-resident consumer.

The compute hot-spot of quantized serving (§Perf hillclimb 2 / EXPERIMENTS
H2-B): y = x @ (q * s) with int8 weights and per-output-channel scales.
Fusing the dequant into the matmul K-loop means the memory system reads
1 byte/weight (the entire point of weight compression) and the f32/bf16
expansion only ever exists tile-at-a-time in VMEM — never in HBM.

Classic tiled-matmul structure: grid (M/bm, N/bn, K/bk), f32 VMEM
accumulator, MXU-aligned 128-multiple tiles, dequant applied to the weight
tile on load.  Validated in interpret mode against ref.py's oracle.

``decompress_dequant_matmul`` is the end-to-end ISSUE-4 consumer: weights
arrive *compressed*, are decoded + zero-point-corrected to int8 on device
(a fused decode ``Epilogue``), and feed the matmul without ever visiting
the host — the full decode→consume path runs under
``transfers.no_host_transfers()``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def ref_dequant_matmul(x: jnp.ndarray, q: jnp.ndarray,
                       s: jnp.ndarray) -> jnp.ndarray:
    """Oracle: x (M,K) @ dequant(q (K,N), s (1,N)) -> (M,N) in x.dtype."""
    w = q.astype(jnp.float32) * s.astype(jnp.float32)
    return (x.astype(jnp.float32) @ w).astype(x.dtype)


def _kernel(x_ref, q_ref, s_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = q_ref[...].astype(jnp.float32) * s_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def dequant_matmul(x: jnp.ndarray, q: jnp.ndarray, s: jnp.ndarray, *,
                   bm: int = 128, bn: int = 128, bk: int = 128,
                   interpret: bool = False) -> jnp.ndarray:
    """x: (M,K) bf16/f32, q: (K,N) int8, s: (1,N) f32 -> (M,N) x.dtype."""
    M, K = x.shape
    _, N = q.shape
    bm_, bn_, bk_ = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm_ == 0 and N % bn_ == 0 and K % bk_ == 0, (M, N, K)
    grid = (M // bm_, N // bn_, K // bk_)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn_), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],  # f32 acc tile
        interpret=interpret,
    )(x, q, s)


# --------------------------------------------------------------------------
# Device-resident consumer: compressed weights in, activations out
# --------------------------------------------------------------------------


def compress_weights(q: np.ndarray, codec: str = "bitpack",
                     zero_point: int = 0,
                     chunk_bytes: int = 64 * 1024):
    """Pack int8 weights for the device-resident matmul path.

    Stores ``q + zero_point`` as uint8 (a zero-point shift keeps low-
    magnitude quantized weights in a narrow non-negative range, which is
    what bitpack exploits: |q| < 2^(b-1) packs at b bits/weight instead of
    8).  Returns the ``api.CompressedArray``; decode with the matching
    epilogue from :func:`weight_epilogue`.
    """
    from repro.core import api
    if q.dtype != np.int8:
        raise ValueError(f"expected int8 weights, got {q.dtype}")
    stored = (q.astype(np.int16) + int(zero_point)).astype(np.uint8)
    return api.compress(stored, codec, chunk_bytes)


def weight_epilogue(zero_point: int = 0):
    """The fused decode epilogue matching :func:`compress_weights`:
    widen the stored uint8 back through the zero-point shift to int8,
    inside the decode dispatch (epilogue operand key ``"epi_zero"``)."""
    from repro.kernels.harness import Epilogue
    return (Epilogue(out_dtype="int8", zero_key="epi_zero"),
            {"epi_zero": np.uint8(zero_point)})


def decompress_dequant_matmul(x: jnp.ndarray, ca, s: jnp.ndarray, *,
                              zero_point: int = 0, engine=None,
                              bm: int = 128, bn: int = 128, bk: int = 128,
                              interpret: bool = False) -> jnp.ndarray:
    """End-to-end device-resident consumer (the ISSUE-4 acceptance path).

    ``ca`` holds (K, N) int8 weights from :func:`compress_weights`.  The
    weights are decoded, scattered to their (K, N) layout, and zero-point-
    corrected to int8 entirely on device (one fused dispatch per codec
    group, epilogue fused in), then consumed by the fused dequant matmul —
    no uint intermediate, no host round trip.

    The staged ``BatchPlan`` (fused tables + scatter + operands, uploaded
    once) is cached on ``ca``, so repeat calls over the same compressed
    weights — the serving steady state — perform no host transfers at all.
    """
    from repro.core import batch as batch_mod
    from repro.core.engine import CodagEngine, EngineConfig
    cached = getattr(ca, "_dqm_plan", None)
    if cached is None or cached[2] != zero_point:
        epi, operands = weight_epilogue(zero_point)
        plan = batch_mod.BatchPlan.build(list(ca.blobs)).stage()
        cached = (plan, (epi, operands), zero_point)
        ca._dqm_plan = cached
    plan, (epi, operands), _ = cached
    [q] = plan.execute_device(engine or CodagEngine(EngineConfig()),
                              epilogue=epi, epilogue_operands=operands)
    return dequant_matmul(x, q, s, bm=bm, bn=bn, bk=bk, interpret=interpret)
