"""Fused int8-dequant matmul — Pallas TPU kernel.

The compute hot-spot of quantized serving (§Perf hillclimb 2 / EXPERIMENTS
H2-B): y = x @ (q * s) with int8 weights and per-output-channel scales.
Fusing the dequant into the matmul K-loop means the memory system reads
1 byte/weight (the entire point of weight compression) and the f32/bf16
expansion only ever exists tile-at-a-time in VMEM — never in HBM.

Classic tiled-matmul structure: grid (M/bm, N/bn, K/bk), f32 VMEM
accumulator, MXU-aligned 128-multiple tiles, dequant applied to the weight
tile on load.  Validated in interpret mode against ref.py's oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def ref_dequant_matmul(x: jnp.ndarray, q: jnp.ndarray,
                       s: jnp.ndarray) -> jnp.ndarray:
    """Oracle: x (M,K) @ dequant(q (K,N), s (1,N)) -> (M,N) in x.dtype."""
    w = q.astype(jnp.float32) * s.astype(jnp.float32)
    return (x.astype(jnp.float32) @ w).astype(x.dtype)


def _kernel(x_ref, q_ref, s_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = q_ref[...].astype(jnp.float32) * s_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def dequant_matmul(x: jnp.ndarray, q: jnp.ndarray, s: jnp.ndarray, *,
                   bm: int = 128, bn: int = 128, bk: int = 128,
                   interpret: bool = False) -> jnp.ndarray:
    """x: (M,K) bf16/f32, q: (K,N) int8, s: (1,N) f32 -> (M,N) x.dtype."""
    M, K = x.shape
    _, N = q.shape
    bm_, bn_, bk_ = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm_ == 0 and N % bn_ == 0 and K % bk_ == 0, (M, N, K)
    grid = (M // bm_, N // bn_, K // bk_)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn_), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],  # f32 acc tile
        interpret=interpret,
    )(x, q, s)
