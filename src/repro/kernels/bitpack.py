"""bitpack unpack — Pallas TPU kernel (fully vectorized, memory-bound).

The one codec in the suite with *no* sequential dependence: element i lives
at bit i*bits, so every VPU lane unpacks independently with a funnel shift —
the pure form of the paper's observation that writing is trivially parallel
once positions are known.  Used for int8/int4 optimizer moments and
quantized KV-cache (optim/grad_compress.py), and as the wire format of the
compressed collectives: distributed/collectives.py builds this exact blob
layout *on device* so gradient syncs decode through the same kernel.

Grid is (num_chunks, elems/TILE): the word row rides along whole (it is
~bits/32 the size of the output tile), the output is tiled (1, TILE) with
TILE=2048 = 16 VREGs of 8x128 — MXU-free, pure VPU+DMA, and the roofline
bench shows it pinned on the HBM term as expected.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core import encoders as enc
from repro.core import format as fmt
from repro.core import registry
from repro.kernels import harness, ref

TILE = 2048


def unpack_tile(words: jnp.ndarray, start, n: int, bits: int) -> jnp.ndarray:
    """Unpack elements [start, start+n) from a uint32 word buffer."""
    idx = start + jnp.arange(n, dtype=jnp.int32)
    bitpos = idx * bits
    w = bitpos >> 5
    off = (bitpos & 31).astype(jnp.uint32)
    w0 = jnp.take(words, w, mode="clip")
    w1 = jnp.take(words, w + 1, mode="clip")
    lo = jnp.right_shift(w0, off)
    sh = (jnp.uint32(32) - off) & jnp.uint32(31)
    hi = jnp.where(off > 0, jnp.left_shift(w1, sh), jnp.uint32(0))
    mask = jnp.uint32(0xFFFFFFFF) if bits == 32 else jnp.uint32((1 << bits) - 1)
    return (lo | hi) & mask


def _kernel(words_ref, out_ref, *, bits: int, tile: int):
    j = pl.program_id(1)
    out_ref[0, :] = unpack_tile(words_ref[0, :], j * tile, tile, bits)


@functools.partial(jax.jit, static_argnames=("bits", "out_elems", "interpret",
                                             "tile"))
def unpack_pallas(words: jnp.ndarray, *, bits: int, out_elems: int,
                  interpret: bool = False, tile: int = TILE) -> jnp.ndarray:
    """words: (num_chunks, W) uint32 -> (num_chunks, out_elems) uint32.

    ``tile`` is the output-tile width (autotunable; default 16 VREGs) —
    smaller tiles raise grid parallelism, larger ones amortize the per-cell
    word-row DMA."""
    n, w = words.shape
    tiles = (out_elems + tile - 1) // tile
    padded = tiles * tile
    out = pl.pallas_call(
        functools.partial(_kernel, bits=bits, tile=tile),
        grid=(n, tiles),
        in_specs=[pl.BlockSpec((1, w), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((1, tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, padded), jnp.uint32),
        interpret=interpret,
    )(words)
    return out[:, :out_elems]


# --------------------------------------------------------------------------
# registry plumbing: DecodeSpec bodies + the Codec entry
# --------------------------------------------------------------------------


def _body(inputs, consts, out_len, *, chunk_elems, width, bits):
    (words,) = inputs
    out = unpack_tile(words, jnp.int32(0), chunk_elems, bits)
    return out.astype(harness.DEV_DTYPE[width])


def _body_scalar(inputs, consts, out_len, *, chunk_elems, width, bits):
    """§V-E single-thread baseline: one element unpacked per loop step."""
    (words,) = inputs
    dt = harness.DEV_DTYPE[width]

    def step(i, buf):
        return buf.at[i].set(unpack_tile(words, i, 1, bits)[0].astype(dt))

    return lax.fori_loop(0, out_len, step, jnp.zeros((chunk_elems,), dt))


def _body_oracle(inputs, consts, out_len, *, chunk_elems, width, bits):
    (words,) = inputs
    return ref.unpack_bits(words, chunk_elems, bits).astype(
        harness.DEV_DTYPE[width])


def _pallas(body, inputs, consts, out_lens, *, chunk_elems, width, bits,
            interpret, tune=()):
    """Hand-tuned override: the output-tiled kernel above (16-VREG tiles)
    instead of the harness's one-chunk-per-cell generic wrapper.  The tile
    width is this codec's declared ``Tunable`` — the autotuner's winning
    value (or an explicit override) arrives via the static ``tune``."""
    (words,) = inputs
    tile = int(dict(tune).get("tile", TILE))
    out = unpack_pallas(words, bits=bits, out_elems=chunk_elems,
                        interpret=interpret, tile=tile)
    return out.astype(harness.DEV_DTYPE[width])


def _demo_data(n, rng):
    """Low-dynamic-range uint32s (gradient-index / quantized-state shaped)."""
    return rng.integers(0, 1 << 9, n).astype("uint32")


CODEC = registry.register(registry.Codec(
    name=fmt.BITPACK,
    encode=enc.compress_bitpack,
    decode=harness.DecodeSpec(
        body=_body,
        body_scalar=_body_scalar,
        body_oracle=_body_oracle,
        chunk_inputs=harness.words_inputs,
        pallas_override=_pallas,
        tunables=(harness.Tunable("tile", (512, 1024, 2048, 4096), TILE),),
    ),
    needs_words=True,
    shared_extras=("bitpack_bits",),
    static_bits=lambda blob: int(blob.extras["bitpack_bits"][0]),
    demo_data=_demo_data,
))
