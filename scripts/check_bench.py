"""Perf-trajectory CI gate: diff fresh BENCH_*.json against baselines.

``benchmarks.run --all --smoke`` writes one ``BENCH_<suite>.json`` per
suite (shared schema ``{name, config, metrics, timestamp}``).  This gate
compares each against the committed reference under
``benchmarks/baselines/`` and fails (exit 1) when the trajectory regresses:

  * a baselined suite produced no artifact, or a baselined metric
    disappeared from it (coverage regression);
  * the run's config differs from the baseline's (the numbers would not
    be comparable — regenerate with ``benchmarks.run --update-baselines``);
  * a DETERMINISTIC metric (compression ratios, symbol lengths, dispatch /
    launch / transfer counts, dataset geometry) drifted at all;
  * with ``--strict``, a TIMING metric left its tolerance band in the bad
    direction (throughput/speedup metrics may not drop below
    ``baseline * (1 - tol)``; latency/compile-time metrics may not rise
    above ``baseline * (1 + tol)``).

Timing metrics are classified by name and SKIPPED by default — shared CI
runners are too noisy to hard-gate wall-clock numbers, so the default gate
is exact on everything machine-independent and silent on the rest.  Metrics
in neither class (window counts, autotuned knob picks, …) are
presence-checked only.

    PYTHONPATH=src python scripts/check_bench.py [--bench-dir .]
        [--strict] [--tol 0.5] [--only SUITE]

Refreshing the reference after an intentional perf/coverage change:

    PYTHONPATH=src python -m benchmarks.run --all --smoke --update-baselines
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
BASELINE_DIR = _ROOT / "benchmarks" / "baselines"

# Machine-independent metrics: same inputs => same value, on any host.
DETERMINISTIC_RE = re.compile(
    r"^(ratio|symlen)/"
    r"|/(n_arrays|n_layers|n_requests|n_tenants|unique_blobs|ndev|groups"
    r"|total_MB|served_MB|weight_MB|compression_ratio|n_leaves|n_windows"
    r"|comp_MB|over_budget|stream_fetches|pressure_evictions|n_pods"
    r"|outer_every|syncs)$"
    r"|launches_per_restore|host_transfers_per_iter|host_bytes_per_iter"
    r"|wire_ratio|wire_MB")

# Wall-clock-derived metrics, split by which direction is a regression.
HIGHER_IS_BETTER_RE = re.compile(
    r"MBps|speedup|tok_s|over_single|over_block|geomean|hit_rate"
    r"|overlap_frac"
    r"|flops_ratio|codecs_improved")
LOWER_IS_BETTER_RE = re.compile(
    r"_ms\b|_ms/|latency|amplification|seconds|_secs|_s$|/t_\w+_s$"
    r"|over_ram")


def classify(name: str) -> str:
    if DETERMINISTIC_RE.search(name):
        return "deterministic"
    if HIGHER_IS_BETTER_RE.search(name):
        return "timing_higher"
    if LOWER_IS_BETTER_RE.search(name):
        return "timing_lower"
    return "info"


def _num(v):
    return v if isinstance(v, (int, float)) and not isinstance(v, bool) else None


def compare(suite: str, base: dict, cur: dict, *, strict: bool,
            tol: float) -> list[str]:
    problems = []
    if base.get("config") != cur.get("config"):
        problems.append(
            f"{suite}: config changed {base.get('config')} -> "
            f"{cur.get('config')} (regenerate baselines)")
        return problems   # numbers are not comparable across configs

    bm, cm = base.get("metrics", {}), cur.get("metrics", {})
    for name in sorted(set(bm) - set(cm)):
        problems.append(f"{suite}: metric {name} disappeared")
    for name in sorted(set(bm) & set(cm)):
        b, c = _num(bm[name]), _num(cm[name])
        if b is None or c is None:
            continue
        kind = classify(name)
        if kind == "deterministic":
            if abs(c - b) > 1e-6 * max(1.0, abs(b)):
                problems.append(
                    f"{suite}: deterministic metric {name} drifted "
                    f"{b} -> {c}")
        elif strict and kind == "timing_higher":
            if c < b * (1.0 - tol):
                problems.append(
                    f"{suite}: {name} regressed {b:.4g} -> {c:.4g} "
                    f"(< {1 - tol:.0%} of baseline)")
        elif strict and kind == "timing_lower":
            if b > 0 and c > b * (1.0 + tol):
                problems.append(
                    f"{suite}: {name} regressed {b:.4g} -> {c:.4g} "
                    f"(> {1 + tol:.0%} of baseline)")
    return problems


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench-dir", default=".",
                    help="where the fresh BENCH_*.json artifacts are")
    ap.add_argument("--baseline-dir", default=str(BASELINE_DIR))
    ap.add_argument("--only", default=None, help="gate a single suite")
    ap.add_argument("--strict", action="store_true",
                    help="also band-check timing metrics (quiet machines)")
    ap.add_argument("--tol", type=float, default=0.5,
                    help="--strict tolerance band (0.5 = 50%%)")
    args = ap.parse_args()

    baseline_dir = Path(args.baseline_dir)
    bench_dir = Path(args.bench_dir)
    baselines = sorted(baseline_dir.glob("BENCH_*.json"))
    if args.only:
        baselines = [p for p in baselines
                     if p.name == f"BENCH_{args.only}.json"]
    if not baselines:
        print(f"BENCH CHECK FAILED: no baselines under {baseline_dir} "
              f"(run benchmarks.run --all --smoke --update-baselines)",
              file=sys.stderr)
        return 1

    problems: list[str] = []
    checked = skipped = 0
    for bp in baselines:
        suite = bp.stem.removeprefix("BENCH_")
        cp = bench_dir / bp.name
        if not cp.exists():
            problems.append(f"{suite}: no fresh artifact at {cp}")
            continue
        base = json.loads(bp.read_text())
        cur = json.loads(cp.read_text())
        problems += compare(suite, base, cur, strict=args.strict,
                            tol=args.tol)
        for name in base.get("metrics", {}):
            kind = classify(name)
            if kind == "deterministic" or (args.strict and
                                           kind.startswith("timing")):
                checked += 1
            else:
                skipped += 1

    if problems:
        for p in problems:
            print(f"BENCH CHECK FAILED: {p}", file=sys.stderr)
        return 1
    mode = "strict" if args.strict else "default"
    print(f"bench trajectory ok: {len(baselines)} suites, "
          f"{checked} metrics gated, {skipped} skipped ({mode} mode)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
