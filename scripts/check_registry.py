"""Registry-completeness CI gate.

Fails (exit 1) if any registered codec is missing from:
  * the fast-tier test matrix (tests/test_codecs.py parametrizes over
    ``registry.names()`` — verified here by importing its module-level
    matrix), or
  * the bench-smoke matrices (benchmarks/batched.py, benchmarks/ablations.py),
    or
  * the golden conformance vectors (tests/vectors/<codec>.json — the
    committed encode/decode fixtures tests/test_conformance.py runs on
    every backend), or
  * the committed tuned-defaults table (src/repro/core/tuned_defaults.json
    — every codec needs an entry, possibly an explicit ``{}``, and knob
    names must be known to core.tuning / the codec's DecodeSpec tunables).

Also validates that every codec's plugin surface is complete enough for
those matrices to actually exercise it (encode/decode hooks + demo data),
and that every codec's decode LOWERS THROUGH THE PLAN IR: each
``ops.decode`` kernel dispatch a round trip issues must originate in
``core.plan.dispatch`` (equal ``plan.count_lowered`` /
``ops.count_dispatches`` records) — a codec wired around the unified
pipeline fails the gate.

    PYTHONPATH=src python scripts/check_registry.py
"""
from __future__ import annotations

import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def main() -> int:
    import numpy as np

    from repro.core import api, format as fmt, registry

    problems: list[str] = []
    names = set(registry.names())

    # every built-in must be registered; EXTRA (third-party) codecs are fine
    # as long as they appear in the matrices below.
    if not set(fmt.CODECS) <= names:
        problems.append(
            f"built-ins {sorted(set(fmt.CODECS) - names)} missing from registry")

    # fast-tier test matrix
    def _diff(what: str, matrix: set) -> None:
        """Name exactly which codecs a matrix is missing / has extra."""
        missing, extra = names - matrix, matrix - names
        if missing:
            problems.append(f"{what}: missing codec(s) {sorted(missing)} "
                            f"(parametrize over registry.names())")
        if extra:
            problems.append(f"{what}: unregistered codec(s) {sorted(extra)} "
                            f"(register them or drop them from the matrix)")

    sys.path.insert(0, str(_ROOT / "tests"))
    try:
        import test_codecs
        _diff("tests/test_codecs.py ALL_CODECS", set(test_codecs.ALL_CODECS))
    finally:
        sys.path.pop(0)

    # bench-smoke matrices
    from benchmarks import ablations, batched
    for mod in (batched, ablations):
        _diff(f"{mod.__name__}.codec_matrix()", set(mod.codec_matrix()))

    # golden conformance vectors: every codec must commit fixtures
    vec_dir = _ROOT / "tests" / "vectors"
    for name in sorted(names):
        vec_file = vec_dir / f"{name}.json"
        if not vec_file.exists():
            problems.append(
                f"{name}: no golden vectors at {vec_file} "
                f"(run scripts/make_vectors.py and commit)")
            continue
        import json
        n_vec = len(json.loads(vec_file.read_text())["vectors"])
        if n_vec < 5:
            problems.append(
                f"{name}: only {n_vec} golden vectors (full matrix expected)")

    # tuned-defaults coverage: every codec must appear in the committed
    # autotune table — an empty {} is the explicit "nothing tuned yet"
    # fallback — and every knob it carries must be one the engine
    # understands (tuning.KNOWN_KNOBS + the codec's own DecodeSpec
    # tunables), so a typo'd knob name cannot silently become a no-op.
    from repro.core import tuning
    tuned = tuning.load_table().get("codecs", {})
    for name in sorted(names):
        if name not in tuned:
            problems.append(
                f"{name}: missing from tuned-defaults table "
                f"({tuning.DEFAULT_TABLE_PATH.name}; an explicit {{}} entry "
                f"counts — run benchmarks.autotune --write-table)")
            continue
        spec = registry.get(name).decode
        allowed = set(tuning.KNOWN_KNOBS) | {
            t.name for t in getattr(spec, "tunables", ())}
        for width_key, kinds in tuned[name].items():
            for kind, knobs in kinds.items():
                unknown = {k for k in knobs if not k.startswith("_")} - allowed
                if unknown:
                    problems.append(
                        f"{name}: unknown tuned knobs {sorted(unknown)} "
                        f"({width_key}/{kind}); allowed: {sorted(allowed)}")

    # plugin surface completeness + a tiny end-to-end round trip per codec,
    # with the plan-lowering gate armed: every kernel dispatch the round
    # trip issues must have been lowered by core.plan.dispatch.
    from repro.core import plan as plan_mod
    from repro.core.engine import CodagEngine, EngineConfig
    from repro.kernels import ops

    engine = CodagEngine(EngineConfig())
    rng = np.random.default_rng(0)
    for name in sorted(names):
        codec = registry.get(name)
        if codec.demo_data is None:
            problems.append(f"{name}: no demo_data (bench matrices need it)")
            continue
        arr = codec.demo_data(256, rng)
        ca = api.compress(arr, name, chunk_bytes=512)
        with plan_mod.count_lowered() as lowered, \
                ops.count_dispatches() as dispatched:
            out = api.decompress(ca, engine)
        if not np.array_equal(out, arr):
            problems.append(f"{name}: demo round trip is not bit-exact")
        if not dispatched:
            problems.append(f"{name}: round trip issued no kernel dispatch")
        elif len(lowered) != len(dispatched):
            problems.append(
                f"{name}: decode bypasses plan lowering "
                f"({len(dispatched)} ops.decode dispatches, only "
                f"{len(lowered)} lowered through core.plan.dispatch)")

    if problems:
        for p in problems:
            print(f"REGISTRY CHECK FAILED: {p}", file=sys.stderr)
        return 1
    print(f"registry complete: {sorted(names)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
