"""Regenerate the generated sections of EXPERIMENTS.md from the dry-run
artifacts.  Keeps the narrative sections; replaces the marked blocks.

    PYTHONPATH=src python scripts/render_experiments.py
"""
import json
import re
from pathlib import Path

RES = Path("experiments/dryrun_results.json")
VAR = Path("experiments/perf_variants.json")
EXP = Path("EXPERIMENTS.md")


def roofline_table(results: dict) -> str:
    lines = ["| arch | shape | mesh | t_comp (s) | t_mem (s) | t_coll (s) "
             "| dominant | useful | mfu_bound |",
             "|---|---|---|---|---|---|---|---|---|"]
    for key, cell in sorted(results.items()):
        parts = key.split("|")
        arch, shape, mesh = parts[:3]
        tag = parts[3] if len(parts) > 3 else ""
        label = f"{arch}{'+' + tag if tag else ''}"
        if cell.get("status") == "skipped":
            lines.append(f"| {label} | {shape} | {mesh} | — | — | — | "
                         f"skipped (full-attn @500k) | — | — |")
            continue
        if cell.get("status") != "ok":
            lines.append(f"| {label} | {shape} | {mesh} | ERROR | | | | | |")
            continue
        r = cell["roofline"]
        lines.append(
            f"| {label} | {shape} | {mesh} | {r['t_compute_s']:.4f} "
            f"| {r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} "
            f"| {r['dominant']} | {r['useful_flops_ratio']:.2f} "
            f"| {r['mfu_bound']:.3f} |")
    return "\n".join(lines)


def memory_table(results: dict) -> str:
    lines = ["| arch | shape | mesh | args GB/dev | temp GB/dev | compile s |",
             "|---|---|---|---|---|---|"]
    for key, cell in sorted(results.items()):
        if cell.get("status") != "ok":
            continue
        arch, shape, mesh = key.split("|")[:3]
        m = cell.get("memory", {})
        lines.append(
            f"| {arch} | {shape} | {mesh} "
            f"| {m.get('argument_size_in_bytes', 0)/1e9:.2f} "
            f"| {m.get('temp_size_in_bytes', 0)/1e9:.2f} "
            f"| {cell.get('compile_s', 0)} |")
    return "\n".join(lines)


def replace_block(text: str, marker: str, content: str) -> str:
    pat = re.compile(
        rf"(<!-- BEGIN {marker} -->).*?(<!-- END {marker} -->)", re.S)
    return pat.sub(rf"\1\n{content}\n\2", text)


def main() -> None:
    text = EXP.read_text()
    if RES.exists():
        results = json.loads(RES.read_text())
        single = {k: v for k, v in results.items() if "|single" in k}
        multi = {k: v for k, v in results.items() if "|multi" in k}
        text = replace_block(text, "ROOFLINE_SINGLE", roofline_table(single))
        text = replace_block(text, "MEM_TABLE", memory_table(results))
    if VAR.exists():
        variants = json.loads(VAR.read_text())
        text = replace_block(text, "PERF_VARIANTS", roofline_table(variants))
    EXP.write_text(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
