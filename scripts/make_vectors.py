"""Regenerate the golden conformance vectors in tests/vectors/.

One JSON file per registered codec; each holds a list of small committed
vectors (raw input + deterministic parameters + the content digest of the
encoded blob).  The conformance suite (tests/test_conformance.py) re-encodes
every vector and asserts the digest matches — locking the encoder's exact
bit output — then decodes it on every backend and asserts bit-exactness.

Run this ONLY when an encoder's output format intentionally changes:

    PYTHONPATH=src python scripts/make_vectors.py

and commit the diff; a digest change that shows up without an intentional
format change is a regression, not a reason to regenerate.
"""
from __future__ import annotations

import base64
import json
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

VEC_DIR = _ROOT / "tests" / "vectors"


def vector_inputs(name: str, codec, rng):
    """Deterministic per-codec vector matrix: generic payloads every codec
    must handle (runs, incompressible, odd tails, single/empty chunks,
    max-width values) plus the codec's own demo distribution."""
    import numpy as np

    cases = [
        # multi-chunk run-heavy u32 (the RLE sweet spot; every codec must
        # still round-trip it)
        ("runs_u32", np.repeat(rng.integers(0, 60, 24).astype(np.uint32),
                               rng.integers(1, 50, 24))[:600], 512, None),
        # incompressible bytes, odd total length
        ("random_u8", rng.integers(0, 256, 397).astype(np.uint8), 256, None),
        # odd tail: last chunk shorter than chunk_elems
        ("odd_tail_u16", (rng.integers(0, 1 << 16, 333)
                          .astype(np.uint16)), 256, None),
        # single element / empty input (chunk-table edge cases)
        ("single_u32", np.asarray([2 ** 31 + 11], np.uint32), 512, None),
        ("empty_u32", np.zeros(0, np.uint32), 512, None),
        # max-width values (full 32-bit range)
        ("maxval_u32", np.concatenate(
            [np.full(40, 2 ** 32 - 1, np.uint32),
             rng.integers(0, 2 ** 32, 60, dtype=np.uint64)
                .astype(np.uint32)]), 256, None),
        # the codec's own representative distribution
        ("demo", codec.demo_data(320, rng), 512, None),
    ]
    if name == "bitpack":
        cases.append(("bits7", (rng.integers(0, 128, 500)
                                .astype(np.uint32)), 512, 7))
    return cases


def main() -> int:
    import numpy as np

    from repro.core import encoders as enc, registry
    from repro.core.server import blob_digest

    VEC_DIR.mkdir(parents=True, exist_ok=True)
    for name in sorted(registry.names()):
        codec = registry.get(name)
        rng = np.random.default_rng(sum(name.encode()))
        vectors = []
        for case, arr, chunk_bytes, bits in vector_inputs(name, codec, rng):
            blob = enc.compress(arr, name, chunk_bytes, bits=bits)
            vectors.append({
                "name": case,
                "dtype": str(arr.dtype),
                "shape": list(arr.shape),
                "chunk_bytes": chunk_bytes,
                "bits": bits,
                "data_b64": base64.b64encode(arr.tobytes()).decode(),
                "blob_digest": blob_digest(blob),
                "num_chunks": blob.num_chunks,
            })
        out = VEC_DIR / f"{name}.json"
        out.write_text(json.dumps(
            {"codec": name, "vectors": vectors}, indent=1))
        print(f"wrote {out} ({len(vectors)} vectors)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
