"""Quickstart: compress on host, decompress on device with the CODAG engine.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import api, format as fmt
from repro.core.engine import CodagEngine, EngineConfig

rng = np.random.default_rng(0)

# a run-heavy integer column (think: ORC analytics data, Table IV)
column = np.repeat(rng.integers(0, 100, 2000).astype(np.uint32),
                   rng.integers(1, 64, 2000))

for codec in (fmt.RLE_V1, fmt.RLE_V2, fmt.TDEFLATE):
    ca = api.compress(column, codec)
    out = api.decompress(ca)                       # device decode (XLA path)
    assert np.array_equal(out, column)
    print(f"{codec:9s}: {column.nbytes/1e6:6.2f} MB -> "
          f"{ca.compressed_bytes/1e6:6.3f} MB  (ratio {ca.ratio:.4f})")

# provisioning strategies (the paper's core subject):
for name, cfgE in {
    "CODAG  warp-unit, all-thread  ": EngineConfig(unit="warp"),
    "RAPIDS block-unit, single-thr.": EngineConfig(unit="block", n_units=8,
                                                   all_thread=False),
}.items():
    eng = CodagEngine(cfgE)
    out = api.decompress(api.compress(column, fmt.RLE_V2), eng)
    assert np.array_equal(out, column)
    print(f"engine [{name}] decode OK")

# the Pallas TPU kernel path, validated in interpret mode on CPU:
eng = CodagEngine(EngineConfig(backend="pallas", interpret=True))
out = api.decompress(api.compress(column, fmt.RLE_V2), eng)
assert np.array_equal(out, column)
print("Pallas kernel (interpret mode) decode OK")
