"""Mesh-sharded compressed restore demo.

A checkpoint saved through the paper's codecs is restored onto a device
mesh: every compressed leaf's chunk rows decode ACROSS the mesh
(``DecodePlan.execute_sharded`` — each device is one more independent
decompressor), and each leaf comes back committed under its requested
``NamedSharding``, with zero device→host funnel crossings on the decode
path.

    PYTHONPATH=src python examples/sharded_restore.py

Forces 8 virtual CPU devices (must happen before jax initializes), so it
runs anywhere.
"""
import os
import tempfile

# must be set before jax initializes; append so existing flags survive
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")

import jax  # noqa: E402  (after the device-count flag)
import numpy as np  # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402

from repro.checkpoint import checkpoint as ckpt  # noqa: E402
from repro.core import format as fmt, transfers  # noqa: E402

rng = np.random.default_rng(0)
state = {
    "embed": rng.normal(size=(512, 128)).astype(np.float32),
    "w_up": rng.normal(size=(128, 256)).astype(np.float32),
    "moments_q": rng.integers(-8, 8, (1024, 128)).astype(np.int8),
}
nbytes = sum(v.nbytes for v in state.values())

if len(jax.devices()) != 8:   # the flag only applies to the CPU platform
    raise SystemExit(f"need 8 devices for the (4, 2) demo mesh, have "
                     f"{len(jax.devices())} — run on CPU or adjust the mesh")
mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
shardings = {
    "embed": NamedSharding(mesh, P("data", "model")),
    "w_up": NamedSharding(mesh, P("model", None)),
    "moments_q": NamedSharding(mesh, P("data", None)),
}

with tempfile.TemporaryDirectory() as d:
    ckpt.save(d, 1, state, codec=fmt.RLE_V2)
    with transfers.count_host_transfers() as c:
        got = ckpt.restore(d, 1, state, shardings=shardings, device_out=True)
    for name, leaf in got.items():
        assert leaf.sharding.is_equivalent_to(shardings[name], leaf.ndim)
        np.testing.assert_array_equal(np.asarray(leaf), state[name])
        print(f"{name:12s} {str(leaf.dtype):8s} {str(leaf.shape):12s} "
              f"born under {leaf.sharding.spec}")
    print(f"restored {nbytes / 1e6:.1f} MB across {len(jax.devices())} "
          f"devices with {c['d2h']} device->host crossings")
print("OK")
