"""Streaming checkpoint restore through the tiered blob store.

A compressed checkpoint BIGGER than the host budget restores window by
window: while window i's leaves decode (DecodePlan stage + dispatch), the
store's prefetch pool is already pulling window i+1's blobs off the
backend, and consumed windows are released back under the byte budget.
The same checkpoint is then restored serially (lookahead disabled by
loading blobs directly) to show the I/O bill the overlap hides.

    PYTHONPATH=src python examples/streaming_restore.py
"""
import tempfile
import time

import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.core import format as fmt
from repro.core import store as bs

rng = np.random.default_rng(0)
state = {f"layer{i:02d}/moments": np.repeat(
             rng.integers(0, 30, 4000).astype(np.int32), 12)
         for i in range(12)}
nbytes = sum(v.nbytes for v in state.values())

with tempfile.TemporaryDirectory() as d:
    ckpt.save(d, 1, state, codec=fmt.RLE_V2)
    step_dir = f"{d}/step_1"
    comp_bytes = sum(p.stat().st_size
                     for p in __import__("pathlib").Path(step_dir).glob("*"))

    # warm the decode jit caches so neither timed restore pays compilation
    ckpt.restore(d, 1, state, decode_window=3)

    # A host budget HALF the checkpoint's compressed size: the whole thing
    # can never be resident — restore must demand-page, decode, release.
    # read_delay_s stands in for an object store's per-read RTT.
    budget = comp_bytes // 2
    with bs.filesystem_store(d, host_budget_bytes=budget,
                             read_delay_s=0.005) as store:
        t0 = time.perf_counter()
        got = ckpt.restore(d, 1, state, store=store, decode_window=3,
                           prefetch_windows=1)
        t_stream = time.perf_counter() - t0
        s = store.stats()

    for k, v in state.items():
        np.testing.assert_array_equal(np.asarray(got[k]), v)

    # the serial baseline: same delayed backend, no lookahead
    with bs.filesystem_store(d, host_budget_bytes=budget,
                             read_delay_s=0.005) as store:
        t0 = time.perf_counter()
        ckpt.restore(d, 1, state, store=store, decode_window=3,
                     prefetch_windows=0)
        t_serial = time.perf_counter() - t0

print(f"checkpoint: {nbytes / 1e6:.2f} MB raw, {comp_bytes / 1e3:.0f} KB "
      f"compressed; host budget {budget / 1e3:.0f} KB (over budget: "
      f"{comp_bytes > budget})")
print(f"paging:     {s.backend_fetches} backend fetches "
      f"({s.backend_bytes_fetched / 1e3:.0f} KB), "
      f"{s.host_released} released + {s.host_evictions} evicted, "
      f"{s.host_bytes} B resident at the end")
print(f"restore:    {t_stream * 1e3:.0f} ms overlapped vs "
      f"{t_serial * 1e3:.0f} ms serial "
      f"({(t_serial - t_stream) * 1e3:.0f} ms of I/O hidden behind decode)")
print("OK")
