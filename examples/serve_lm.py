"""Batched serving example: prefill + cached greedy decode.

    PYTHONPATH=src python examples/serve_lm.py
"""
import subprocess
import sys

cmd = [sys.executable, "-m", "repro.launch.serve",
       "--arch", "zamba2-2.7b", "--preset", "tiny",
       "--batch", "4", "--prompt-len", "32", "--gen", "16"]
print("+", " ".join(cmd))
raise SystemExit(subprocess.call(cmd))
