"""Codec-compressed, atomic, async checkpointing demo.

Saves a model's training state through the paper's codecs and restores it
bit-exact — the decompression engine in the checkpoint data plane.

    PYTHONPATH=src python examples/compressed_checkpoint.py
"""
import tempfile
import time

import jax
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs import get_arch, reduced
from repro.core import format as fmt
from repro.models import model
from repro.optim import adamw

cfg = reduced(get_arch("qwen3-1.7b"))
params = model.init_params(cfg, jax.random.key(0))
opt = adamw.init(params, adamw.AdamWConfig(compress_moments=True))
state = {"params": params, "opt": opt}
nbytes = sum(np.asarray(x).nbytes for x in jax.tree.leaves(state))

with tempfile.TemporaryDirectory() as d:
    t0 = time.time()
    thread = ckpt.save(d, 100, state, codec=fmt.RLE_V2, async_=True)
    print(f"async save dispatched in {time.time()-t0:.3f}s "
          f"(snapshot taken; writer on background thread)")
    thread.join()
    print(f"written in {time.time()-t0:.2f}s, state={nbytes/1e6:.1f} MB")

    got = ckpt.restore(d, 100, state)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), state, got)
    print("restore bit-exact OK")

    # int8 moments are where compression bites (quantized state + rle)
    import json, pathlib
    man = json.loads((pathlib.Path(d) / "step_100" / "manifest.json").read_text())
    ratios = [e.get("ratio") for e in man["leaves"].values() if "ratio" in e]
    print(f"{len(ratios)} leaves codec-compressed, "
          f"mean stored ratio {np.mean(ratios):.3f}")
print("OK")
