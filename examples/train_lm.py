"""End-to-end training example: ~100M-param model, a few hundred steps,
compressed data pipeline + fault-tolerant checkpointed loop.

    PYTHONPATH=src python examples/train_lm.py                  # quick (tiny)
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 200

This is a thin veneer over the production driver (repro.launch.train); the
driver itself is the example.
"""
import subprocess
import sys
import argparse

ap = argparse.ArgumentParser()
ap.add_argument("--preset", default="small")
ap.add_argument("--steps", default="120")
ap.add_argument("--arch", default="olmo-1b")
args = ap.parse_args()

cmd = [sys.executable, "-m", "repro.launch.train",
       "--arch", args.arch, "--preset", args.preset,
       "--steps", args.steps, "--batch", "4", "--seq", "256",
       "--ckpt-dir", "/tmp/repro_example_ckpt",
       "--codec", "rle_v2"]
print("+", " ".join(cmd))
raise SystemExit(subprocess.call(cmd))
