"""Compressed collectives demo: DiLoCo outer sync over a registry-codec wire.

Runs on 8 fake CPU devices (2 pods x 4 data): two pod replicas train
locally, then reconcile through a compressed collective across the slow
'pod' axis — each pod's delta is encoded into the bitpack codec's exact
wire layout ON DEVICE, the compressed bytes + chunk tables are all-gathered
inside shard_map, and the receive path decodes through ``plan.dispatch``
with the dequant + member-mean fused into the decode epilogue (the Nesterov
outer step consumes the decode output directly).  The sync pipeline
overlaps the collective with the next window's inner steps.

    PYTHONPATH=src python examples/grad_compression.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.distributed import collectives, diloco

mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("pod", "data"))
print("mesh:", dict(mesh.shape))

# a toy per-pod 'model': params trained toward pod-specific targets
params = {"w": jnp.zeros((1024,))}
pod_params = diloco.replicate_for_pods(params, 2, mesh)
targets = jnp.stack([jnp.full((1024,), 1.0), jnp.full((1024,), 2.0)])


def inner_step(p, t):
    g = 2 * (p["w"] - t)
    return {"w": p["w"] - 0.05 * g}


cfg = diloco.DiLoCoConfig(inner_steps=8, outer_lr=1.0, outer_momentum=0.0,
                          wire="int8")
outer = diloco.init_outer_state(params, mesh=mesh, cfg=cfg)
sync = jax.jit(diloco.make_outer_sync(mesh, cfg))
pipe = diloco.OuterSyncPipeline(sync, link_rtt_s=0.05)

with mesh:
    jit_inner = jax.jit(jax.vmap(inner_step))
    for window in range(10):
        # the previous window's collective drains WHILE these inner steps
        # run; finish() merges inner progress onto the rebased anchor
        if pipe.in_flight:
            pod_params, outer = pipe.finish(pod_params)
        pipe.launch(pod_params, outer)
        for _ in range(cfg.inner_steps):
            pod_params = jit_inner(pod_params, targets)
        anchor_mean = float(outer["anchor"]["w"].mean())
        print(f"window {window}: anchor mean={anchor_mean:.4f} "
              f"(target consensus: 1.5)")
    pod_params, outer = pipe.finish(pod_params)

st = pipe.stats()
print(f"\noverlap: {st['syncs']} syncs, "
      f"{st['overlap_frac']*100:.0f}% of {st['collective_s']:.2f}s "
      f"collective hidden behind inner steps")

rep = {w: collectives.wire_report(params, 2, wire=w, frac=0.01)
       for w in ("none", "int8", "topk")}
print("wire bytes/outer-sync per pod member:")
print(f"  f32 ring all-reduce : {rep['none']['f32_ring_bytes']:,.0f}")
print(f"  int8 bitpack wire   : {rep['int8']['wire_bytes']:,.0f} "
      f"({rep['int8']['ratio']:.1f}x less)")
print(f"  top-1% + bitmask    : {rep['topk']['wire_bytes']:,.0f} "
      f"({rep['topk']['ratio']:.1f}x less)")
assert abs(float(outer["anchor"]["w"].mean()) - 1.5) < 0.05
assert st["overlap_frac"] > 0.3    # the >=50% bar is benchmarks/collectives
print("OK")
