"""Cross-pod gradient/parameter compression demo (DiLoCo-style outer sync).

Runs on 8 fake CPU devices (2 pods x 2 data x 2 model): two pod replicas
train locally, then reconcile through an int8-compressed all-reduce across
the slow 'pod' axis — the paper's compression thesis applied to collectives.

    PYTHONPATH=src python examples/grad_compression.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.distributed import diloco
from repro.optim.grad_compress import (topk_wire_bytes,
                                       wire_bytes_compressed,
                                       wire_bytes_f32_allreduce)

mesh = Mesh(np.asarray(jax.devices()).reshape(2, 2, 2),
            ("pod", "data", "model"))
print("mesh:", dict(mesh.shape))

# a toy per-pod 'model': params trained toward pod-specific targets
params = {"w": jnp.zeros((1024,))}
pod_params = diloco.replicate_for_pods(params, 2, mesh)
targets = jnp.stack([jnp.full((1024,), 1.0), jnp.full((1024,), 2.0)])


def inner_step(p, t):
    g = 2 * (p["w"] - t)
    return {"w": p["w"] - 0.05 * g}


anchor, mom = diloco.init_outer_state(params)
sync = diloco.make_outer_sync(mesh, diloco.DiLoCoConfig(
    inner_steps=8, outer_lr=1.0, outer_momentum=0.0, compress=True))

with mesh:
    jit_inner = jax.jit(jax.vmap(inner_step))
    jit_sync = jax.jit(sync)
    for outer in range(5):
        for _ in range(8):
            pod_params = jit_inner(pod_params, targets)
        pod_params, anchor, mom = jit_sync(pod_params, anchor, mom)
        print(f"outer {outer}: anchor mean={float(anchor['w'].mean()):.4f} "
              f"(target consensus: 1.5)")

n_bytes = params["w"].size * 4
print(f"\nwire bytes/outer-sync per pod member:")
print(f"  f32 ring all-reduce : {wire_bytes_f32_allreduce(n_bytes, 2):,.0f}")
print(f"  int8 compressed     : {wire_bytes_compressed(n_bytes, 2):,.0f}")
print(f"  top-1% + bitmask    : {topk_wire_bytes(params['w'].size, 0.01):,.0f}")
assert abs(float(anchor["w"].mean()) - 1.5) < 0.05
print("OK")
