"""Batch decompression scheduler: grouping, dispatch accounting, scatter-back.

Covers the ISSUE-1 acceptance criterion: ``api.decompress_many`` over >= 8
mixed-codec blobs is bit-exact vs per-blob ``api.decompress`` and issues
exactly one ``ops.decode`` dispatch per (codec, width, chunk_elems, bits)
group, verified by monkeypatching ``ops.decode``.
"""
import numpy as np
import pytest

from repro.core import api, batch, encoders as enc, format as fmt, registry
from repro.core.engine import CodagEngine, EngineConfig
from repro.kernels import ops

RNG = np.random.default_rng(11)


def _runs_u32(n):
    vals = RNG.integers(0, 90, max(4, n // 40)).astype(np.uint32)
    return np.repeat(vals, RNG.integers(1, 80, len(vals)))[:n]


def mixed_arrays():
    """>= 8 arrays spanning EVERY registered codec and three widths."""
    items = [
        (_runs_u32(900), fmt.RLE_V1),
        (RNG.integers(0, 250, 400).astype(np.uint8), fmt.RLE_V1),
        (_runs_u32(700), fmt.RLE_V2),
        ((np.arange(500) * 5 + 2).astype(np.uint16), fmt.RLE_V2),
        (np.repeat(RNG.integers(0, 2 ** 40, 15).astype(np.uint64),
                   RNG.integers(1, 40, 15)), fmt.RLE_V2),
        (np.frombuffer(b"batched codag streams " * 30, np.uint8).copy(),
         fmt.TDEFLATE),
        (np.frombuffer(b"abcabcabc" * 70, np.uint8).copy(), fmt.TDEFLATE),
        (RNG.integers(0, 2 ** 7, 1200).astype(np.uint32), fmt.BITPACK),
        (RNG.integers(0, 2 ** 7, 600).astype(np.uint32), fmt.BITPACK),
    ]
    # every registered codec rides the batch path (dbp + future plugins)
    covered = {c for _, c in items}
    for name in registry.names():
        if name not in covered:
            items.append((registry.get(name).demo_data(800, RNG), name))
    return items


@pytest.fixture
def counted():
    """List of per-dispatch records from the shared ops.decode counter."""
    with ops.count_dispatches() as calls:
        yield calls


def test_mixed_codec_roundtrip_bit_exact(counted):
    items = mixed_arrays()
    assert len(items) >= 8
    cas = api.compress_many([a for a, _ in items], [c for _, c in items],
                            chunk_bytes=600)
    eng = CodagEngine(EngineConfig())
    batched = api.decompress_many(cas, eng)
    n_batched = len(counted)
    counted.clear()
    per_blob = [api.decompress(ca, eng) for ca in cas]
    n_loop = len(counted)

    for (arr, codec), got_b, got_p in zip(items, batched, per_blob):
        assert got_b.dtype == arr.dtype and got_b.shape == arr.shape, codec
        assert np.array_equal(got_b, arr), codec
        assert np.array_equal(got_b, got_p), codec

    # one dispatch per distinct group key; the loop pays one per blob
    flat = [b for ca in cas for b in ca.blobs]
    n_groups = len({fmt.group_key(b) for b in flat})
    assert n_batched == n_groups
    assert n_loop == len(flat)
    assert n_batched < n_loop


def test_one_dispatch_per_group_key(counted):
    """Exactly one ops.decode call per (codec, width, chunk_elems, bits)."""
    arrays = [_runs_u32(800) for _ in range(5)]        # same key -> 1 dispatch
    arrays += [RNG.integers(0, 200, 640).astype(np.uint8)]  # width 1 -> new key
    cas = api.compress_many(arrays, fmt.RLE_V2, chunk_bytes=512)
    api.decompress_many(cas)
    assert len(counted) == 2
    # the fused dispatch really carries every chunk of its group
    per_key = {}
    for c in counted:
        per_key[(c["codec"], c["width"], c["chunk_elems"])] = c["num_chunks"]
    chunks_u32 = sum(b.num_chunks for ca in cas[:5] for b in ca.blobs)
    assert per_key[(fmt.RLE_V2, 4, 128)] == chunks_u32


def test_scatter_back_ordering():
    """Outputs follow input order even with interleaved group membership."""
    a_u32 = [np.full(100 + i, i, np.uint32) for i in range(4)]
    a_u8 = [np.full(50 + i, 7 + i, np.uint8) for i in range(4)]
    arrays = [x for pair in zip(a_u32, a_u8) for x in pair]  # interleave keys
    cas = api.compress_many(arrays, fmt.RLE_V1, chunk_bytes=256)
    outs = api.decompress_many(cas)
    for arr, out in zip(arrays, outs):
        assert np.array_equal(out, arr)


def test_empty_and_single_blob_edges(counted):
    assert api.decompress_many([]) == []
    assert batch.decompress_blobs([]) == []
    assert len(counted) == 0

    arr = _runs_u32(512)
    (out,) = api.decompress_many([api.compress(arr, fmt.RLE_V2,
                                               chunk_bytes=512)])
    assert np.array_equal(out, arr)
    assert len(counted) == 1


def test_plan_structure_and_merged_table():
    blobs = [enc.compress(_runs_u32(600), fmt.RLE_V1, 512) for _ in range(3)]
    blobs.append(enc.compress(RNG.integers(0, 9, 300).astype(np.uint8),
                              fmt.RLE_V1, 512))
    plan = batch.BatchPlan.build(blobs)
    assert plan.num_dispatches == 2
    g = plan.groups[0]
    assert g.blob_ids == (0, 1, 2)
    assert g.row_offsets == (0, blobs[0].num_chunks,
                             blobs[0].num_chunks + blobs[1].num_chunks)
    assert g.merged.num_chunks == sum(b.num_chunks for b in blobs[:3])
    assert g.merged.total_elems == sum(b.total_elems for b in blobs[:3])
    # merged comp rows preserve each blob's bytes
    row = blobs[0].num_chunks
    np.testing.assert_array_equal(
        g.merged.comp[row:row + blobs[1].num_chunks, :blobs[1].comp.shape[1]],
        blobs[1].comp)


def test_concat_blobs_rejects_mixed_keys():
    b1 = enc.compress(_runs_u32(600), fmt.RLE_V1, 512)
    b2 = enc.compress(_runs_u32(600), fmt.RLE_V2, 512)
    with pytest.raises(ValueError, match="group key"):
        fmt.concat_blobs([b1, b2])


def test_heterogeneous_comp_widths_merge():
    """Blobs whose comp tables have different max row lengths still fuse."""
    nearly_raw = RNG.integers(0, 255, 2048).astype(np.uint8)   # wide rows
    runs = np.repeat(np.uint8(3), 2048)                        # narrow rows
    cas = api.compress_many([nearly_raw, runs], fmt.RLE_V1, chunk_bytes=512)
    outs = api.decompress_many(cas)
    assert np.array_equal(outs[0], nearly_raw)
    assert np.array_equal(outs[1], runs)


def test_batched_engine_config_respected(counted):
    """The scheduler funnels through whatever engine it is handed."""
    arrays = [_runs_u32(700), _runs_u32(900)]
    cas = api.compress_many(arrays, fmt.RLE_V2, chunk_bytes=512)
    outs = api.decompress_many(cas, CodagEngine(EngineConfig(
        unit="block", n_units=2)))
    for arr, out in zip(arrays, outs):
        assert np.array_equal(out, arr)
    assert len(counted) == 1  # block unit still traces one decode


def test_mixed_arrays_cover_registry():
    """The batch matrix spans the full registry (completeness guard)."""
    assert {c for _, c in mixed_arrays()} == set(registry.names())


def test_dbp_batched_single_dispatch_group(counted):
    """ISSUE-2 acceptance: several dbp blobs fuse into ONE dispatch group
    through ``api.decompress_many``, bit-exactly."""
    arrays = [np.cumsum(RNG.integers(0, 9, 700 + 37 * i)).astype(np.uint32)
              for i in range(4)]
    cas = api.compress_many(arrays, fmt.DBP, chunk_bytes=512)
    outs = api.decompress_many(cas)
    for arr, out in zip(arrays, outs):
        assert np.array_equal(out, arr)
    assert len(counted) == 1
    assert counted[0]["codec"] == fmt.DBP
    assert counted[0]["num_chunks"] == sum(
        b.num_chunks for ca in cas for b in ca.blobs)


def test_tdeflate_per_chunk_luts_travel_with_merge():
    """tdeflate extras are per-chunk tables; merging must keep row alignment."""
    texts = [(b"x" * 37 + bytes([i])) * 60 for i in range(6)]
    arrays = [np.frombuffer(t, np.uint8).copy() for t in texts]
    cas = api.compress_many(arrays, fmt.TDEFLATE, chunk_bytes=512)
    outs = api.decompress_many(cas)
    for arr, out in zip(arrays, outs):
        assert out.tobytes() == arr.tobytes()
