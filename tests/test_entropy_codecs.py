"""Entropy-coded codec family (huffman + lzss): the edge cases the generic
registry matrix cannot force.

The registry-parametrized suites (test_codecs.py, test_conformance.py,
test_codecs_properties.py) already cover both codecs on every backend,
width, and chunk shape.  This file pins the failure modes specific to
variable-length symbol streams:

  * huffman — degenerate single-symbol alphabets (code length 1, no
    sibling), length-limited canonical codes when the Kraft fixup binds
    (skewed Fibonacci frequencies would want > MAX_CODE_BITS), and gap
    array segment boundaries (chunk lengths straddling SUB);
  * lzss — overlapping back-references (dist < length: dist=1 constant
    runs, period-3 tiles), where a naive vector copy reads bytes the same
    copy has not produced yet;
  * both — the tuned-knob candidates (sub_unroll / dbl_unroll) and the
    pipelined Pallas wrapper must stay bit-exact vs the XLA reference.
"""
import numpy as np
import pytest

from repro.core import api, encoders as enc, registry, tuning
from repro.core.engine import CodagEngine, EngineConfig
from repro.kernels import huffman as hf
from repro.kernels import lzss as lz

RNG = np.random.default_rng(17)

# xla / oracle / scalar cover the three decode disciplines cheaply; the
# interpret-mode Pallas engine is exercised once per codec in the tuned-knob
# test below.
ENGINES = {
    "xla": EngineConfig(unit="warp", backend="xla"),
    "oracle": EngineConfig(unit="warp", backend="oracle"),
    "scalar": EngineConfig(unit="warp", all_thread=False),
}


def _roundtrip_all(arr, codec, chunk_bytes):
    ca = api.compress(arr, codec, chunk_bytes=chunk_bytes)
    for name, cfg in ENGINES.items():
        got = api.decompress(ca, CodagEngine(cfg))
        assert got.dtype == arr.dtype, f"{codec}/{name}"
        assert np.array_equal(got, arr), f"{codec}/{name}"
    return ca


# --------------------------------------------------------------------------
# huffman
# --------------------------------------------------------------------------


def test_huffman_single_symbol_alphabet():
    """One active symbol: the canonical code is a single 1-bit codeword —
    no sibling to pair with, so the tree-build degenerate path runs."""
    hist = np.bincount(np.full(64, 9, np.uint8), minlength=256)
    lens = enc.limited_huffman_lengths(hist, enc.MAX_CODE_BITS)
    assert lens[9] == 1 and np.count_nonzero(lens) == 1
    for n in (1, 64, 1000):
        _roundtrip_all(np.full(n, 9, np.uint8), hf.HUFFMAN, chunk_bytes=600)


def test_huffman_max_code_length_kraft_fixup():
    """Fibonacci-skewed frequencies want codes deeper than MAX_CODE_BITS;
    the length-limit fixup must bind (some code AT the cap, none over) and
    the limited code must still round-trip everywhere."""
    counts = [1, 1]
    while len(counts) < 24:
        counts.append(counts[-1] + counts[-2])
    data = np.repeat(np.arange(len(counts), dtype=np.uint8),
                     counts).astype(np.uint8)
    RNG.shuffle(data)
    hist = np.bincount(data, minlength=256)
    lens = enc.limited_huffman_lengths(hist, enc.MAX_CODE_BITS)
    active = lens[lens > 0]
    assert active.max() == enc.MAX_CODE_BITS     # the cap binds...
    assert np.sum(0.5 ** active.astype(np.float64)) <= 1.0   # ...Kraft holds
    _roundtrip_all(data, hf.HUFFMAN, chunk_bytes=4096)
    _roundtrip_all(data, hf.HUFFMAN, chunk_bytes=777)   # multi-chunk + tail


@pytest.mark.parametrize("n", [hf.SUB - 1, hf.SUB, hf.SUB + 1,
                               2 * hf.SUB, 5 * hf.SUB + 3])
def test_huffman_gap_segment_boundaries(n):
    """Chunk lengths straddling the SUB-symbol gap-array granularity: the
    last segment may hold 1..SUB symbols and its count byte must agree."""
    data = np.minimum(RNG.geometric(0.3, n) - 1, 255).astype(np.uint8)
    ca = _roundtrip_all(data, hf.HUFFMAN, chunk_bytes=1 << 14)
    row = np.asarray(ca.blobs[0].comp[0])
    n_seg = hf.CODEC.count_groups(row, 1)
    assert n_seg == -(-n // hf.SUB)              # gap table is recoverable


# --------------------------------------------------------------------------
# lzss
# --------------------------------------------------------------------------


def test_lzss_overlapping_backref_dist1():
    """dist=1, length up to MAX_MATCH: every copied element is produced by
    the same copy — the pointer-doubling resolution's worst case."""
    for width, dt in ((1, np.uint8), (2, np.uint16), (4, np.uint32)):
        arr = np.full(500, 7, dt)
        tok = lz.encode_lzss_chunk(arr, width)
        # literal control for element 0, then a match token with dist=1
        assert tok[0] == 0 and tok[1 + width] >= 128
        assert int.from_bytes(tok[2 + width:4 + width], "little") == 1
        _roundtrip_all(arr, lz.LZSS, chunk_bytes=600)


def test_lzss_overlapping_backref_period3():
    """Period-3 tiles: dist=3 < match length, chains of matches pointing
    into earlier matches (multi-hop pointer doubling)."""
    for dt in (np.uint8, np.uint32):
        arr = np.tile(np.asarray([11, 250, 3], dt), 700)
        _roundtrip_all(arr, lz.LZSS, chunk_bytes=777)
    # noisy variant: literals interrupt the chains mid-stream
    arr = np.tile(np.asarray([11, 250, 3], np.uint32), 700)
    idx = RNG.integers(0, arr.size, 40)
    arr[idx] = RNG.integers(0, 1 << 16, 40)
    _roundtrip_all(arr, lz.LZSS, chunk_bytes=913)


# --------------------------------------------------------------------------
# tuned knobs + pipelined wrapper stay bit-exact
# --------------------------------------------------------------------------


@pytest.mark.parametrize("codec", [hf.HUFFMAN, lz.LZSS])
def test_tuned_knob_candidates_bit_exact(codec):
    """Every candidate of every codec tunable (sub_unroll / dbl_unroll)
    must decode identically — knobs trade speed, never values — including
    through the multi-stage pipelined Pallas wrapper."""
    c = registry.get(codec)
    arr = c.demo_data(3000, np.random.default_rng(5))
    ca = api.compress(arr, codec, chunk_bytes=512)
    with tuning.override(None):
        ref = api.decompress(ca, CodagEngine(EngineConfig(backend="xla")))
        np.testing.assert_array_equal(ref, arr)
        for t in c.decode.tunables:
            for v in t.candidates:
                got = api.decompress(ca, CodagEngine(EngineConfig(
                    backend="pallas", interpret=True,
                    tune=((t.name, v), ("interpret_pipeline", 1),
                          ("num_stages", 3)))))
                np.testing.assert_array_equal(
                    got, arr, err_msg=f"{t.name}={v}")
