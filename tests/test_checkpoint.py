"""Checkpointing: atomic publish, async, codec compression, retention,
fault-tolerant runner restart, straggler detection."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.core import format as fmt
from repro.distributed import fault


def _state(seed=0):
    k = jax.random.key(seed)
    return {"w": jax.random.normal(k, (64, 32)),
            "b": jnp.arange(10, dtype=jnp.int32),
            "nested": {"m": jnp.ones((128,), jnp.float32) * 3}}


def test_save_restore_roundtrip(tmp_path):
    s = _state()
    ckpt.save(str(tmp_path), 5, s)
    assert ckpt.latest_step(str(tmp_path)) == 5
    got = ckpt.restore(str(tmp_path), 5, s)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), s, got)


def test_async_save(tmp_path):
    s = _state()
    t = ckpt.save(str(tmp_path), 1, s, async_=True)
    assert t is not None
    t.join(timeout=30)
    got = ckpt.restore(str(tmp_path), 1, s)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(s["w"]))


@pytest.mark.parametrize("codec", [fmt.RLE_V2, fmt.TDEFLATE])
def test_compressed_checkpoint(tmp_path, codec):
    s = {"ints": jnp.asarray(np.repeat(np.arange(50, dtype=np.int32), 40)),
         "f32": jnp.ones((2048,), jnp.float32)}
    ckpt.save(str(tmp_path), 2, s, codec=codec)
    got = ckpt.restore(str(tmp_path), 2, s)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), s, got)


def test_compressed_restore_is_batched(tmp_path):
    """Restoring N compressed tensors coalesces into one decode dispatch per
    codec group instead of one per tensor (CODAG provisioning)."""
    from repro.kernels import ops

    s = {f"layer{i}": jnp.asarray(np.repeat(np.arange(40, dtype=np.int32), 60))
         for i in range(6)}
    ckpt.save(str(tmp_path), 3, s, codec=fmt.RLE_V2)

    with ops.count_dispatches() as calls:
        got = ckpt.restore(str(tmp_path), 3, s)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), s, got)
    # 6 same-key leaves -> exactly one fused dispatch carrying all chunks
    assert len(calls) == 1

    # bounded-memory variant: one dispatch per window of 2 leaves
    with ops.count_dispatches() as calls:
        got = ckpt.restore(str(tmp_path), 3, s, decode_window=2)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), s, got)
    assert len(calls) == 3


def test_restore_through_service(tmp_path):
    """restore(service=) decodes every compressed leaf through one
    DecompressionService — bit-exact, and all same-group leaves still share
    one fused dispatch (now issued by the service worker)."""
    from repro.core.server import DecompressionService
    from repro.kernels import ops

    s = {f"layer{i}": jnp.asarray(np.repeat(np.arange(40, dtype=np.int32), 60))
         for i in range(6)}
    ckpt.save(str(tmp_path), 4, s, codec=fmt.RLE_V2)

    with DecompressionService(cache_bytes=0, bucket_shapes=False) as svc:
        with ops.count_dispatches() as calls:
            got = ckpt.restore(str(tmp_path), 4, s, service=svc)
        stats = svc.stats()
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), s, got)
    assert len(calls) == 1
    assert stats.blobs == 6 and stats.dispatches == 1

    # engine= and service= pick different decode owners; both is an error
    from repro.core.engine import CodagEngine
    with DecompressionService() as svc2:
        with pytest.raises(ValueError, match="not both"):
            ckpt.restore(str(tmp_path), 4, s, service=svc2,
                         engine=CodagEngine())


def test_retention(tmp_path):
    s = _state()
    for step in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), step, s, keep=2)
    steps = sorted(ckpt.all_steps(str(tmp_path)))
    assert steps == [4, 5]


def test_elastic_restore_changes_layout(tmp_path):
    """Restore with explicit shardings (single device: identity layout,
    exercises the device_put path the elastic restart uses)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    s = _state()
    ckpt.save(str(tmp_path), 3, s)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), s)
    got = ckpt.restore(str(tmp_path), 3, s, shardings=sh)
    assert got["w"].sharding == sh["w"]


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


def _quadratic_step(state, batch):
    w = state["w"]
    g = 2 * (w - batch)
    w = w - 0.1 * g
    return {"w": w}, float(jnp.sum((w - batch) ** 2))


def test_runner_restarts_from_checkpoint(tmp_path):
    target = jnp.ones((4,))
    injector = fault.FailureInjector(fail_at_steps=[7, 13])
    runner = fault.FaultTolerantRunner(
        _quadratic_step, str(tmp_path), ckpt_every=5, injector=injector,
        async_ckpt=False)
    batches = (target for _ in iter(int, 1))
    state, report = runner.run({"w": jnp.zeros((4,))}, batches, 20)
    assert report.steps_done == 20
    assert report.restarts == 2
    assert report.losses[-1] < 1e-3


def test_runner_gives_up_after_max_restarts(tmp_path):
    injector = fault.FailureInjector(fail_at_steps=[1])

    class AlwaysFail(fault.FailureInjector):
        def maybe_fail(self, step):
            raise fault.WorkerFailure("dead node")

    runner = fault.FaultTolerantRunner(
        _quadratic_step, str(tmp_path), ckpt_every=5,
        injector=AlwaysFail(), max_restarts=2, async_ckpt=False)
    with pytest.raises(fault.WorkerFailure):
        runner.run({"w": jnp.zeros((4,))},
                   (jnp.ones((4,)) for _ in iter(int, 1)), 10)


def test_straggler_detection():
    mon = fault.StepMonitor(straggler_factor=3.0)
    for i in range(10):
        mon.observe(i, 0.1)
    rec = mon.observe(10, 0.55)
    assert rec.straggler
    assert len(mon.stragglers) == 1
    assert mon.healthy(timeout=60)


def test_resume_from_existing_checkpoint(tmp_path):
    """A fresh runner resumes at the last checkpointed step."""
    target = jnp.ones((4,))
    r1 = fault.FaultTolerantRunner(_quadratic_step, str(tmp_path),
                                   ckpt_every=5, async_ckpt=False)
    state, rep1 = r1.run({"w": jnp.zeros((4,))},
                         (target for _ in iter(int, 1)), 10)
    r2 = fault.FaultTolerantRunner(_quadratic_step, str(tmp_path),
                                   ckpt_every=5, async_ckpt=False)
    state2, rep2 = r2.run({"w": jnp.zeros((4,))},
                          (target for _ in iter(int, 1)), 15)
    # resumed from step 10, ran only 5 more
    assert rep2.steps_done == 15
    assert len(rep2.losses) == 5
