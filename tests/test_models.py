"""Per-architecture smoke tests (reduced configs): forward/train-step shape
+ NaN checks, decode-vs-forward agreement, unroll-vs-scan equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs, reduced
from repro.launch import steps as steps_lib
from repro.models import model
from repro.optim import adamw

ARCHS = list_archs()
KEY = jax.random.key(0)


def _inputs(cfg, B=2, S=32):
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab)
    prefix = (jnp.zeros((B, cfg.n_prefix, cfg.d_model), jnp.float32)
              if cfg.n_prefix else None)
    return tokens, labels, prefix


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nans(arch):
    cfg = reduced(get_arch(arch))
    params = model.init_params(cfg, KEY)
    tokens, _, prefix = _inputs(cfg)
    logits = jax.jit(lambda p, t: model.forward(cfg, p, t, prefix))(
        params, tokens)
    assert logits.shape == (2, 32 + cfg.n_prefix, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_runs_and_is_finite(arch):
    cfg = reduced(get_arch(arch))
    params = model.init_params(cfg, KEY)
    opt_cfg = adamw.AdamWConfig(lr=1e-3)
    opt_state = adamw.init(params, opt_cfg)
    tokens, labels, prefix = _inputs(cfg)
    batch = {"tokens": tokens, "labels": labels}
    if prefix is not None:
        batch["prefix_emb"] = prefix
    step = jax.jit(steps_lib.build_train_step(cfg, opt_cfg))
    params2, opt2, loss = step(params, opt_state, batch)
    assert np.isfinite(float(loss))
    # params actually changed (skip 0-size non-param LN placeholders)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))) if a.size else 0.0,
        params, params2)
    assert max(jax.tree.leaves(d)) > 0


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = reduced(get_arch(arch))
    if cfg.n_prefix:
        pytest.skip("prefix archs: decode tested without modality prefix")
    params = model.init_params(cfg, KEY)
    tokens, _, _ = _inputs(cfg, B=2, S=8)
    full = model.forward(cfg, params, tokens)
    cache = model.init_cache(cfg, 2, 16)
    step = jax.jit(lambda p, c, t: model.decode_step(cfg, p, c, t))
    outs = []
    for i in range(8):
        lg, cache = step(params, cache, tokens[:, i:i + 1])
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full, np.float32),
                               np.asarray(dec, np.float32),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen3-1.7b", "qwen3-moe-235b-a22b",
                                  "zamba2-2.7b", "rwkv6-1.6b"])
def test_unroll_matches_scan(arch):
    """The dry-run probe path (python-unrolled) must be numerically
    identical to the production scan path."""
    cfg = reduced(get_arch(arch))
    params = model.init_params(cfg, KEY)
    tokens, labels, prefix = _inputs(cfg)
    l_scan = model.loss_fn(cfg, params, tokens, labels, prefix, remat=False)
    l_unroll = model.loss_fn(cfg, params, tokens, labels, prefix,
                             remat=False, unroll=True)
    np.testing.assert_allclose(float(l_scan), float(l_unroll),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen3-1.7b", "zamba2-2.7b"])
def test_decode_unroll_matches_scan(arch):
    cfg = reduced(get_arch(arch))
    params = model.init_params(cfg, KEY)
    tokens, _, _ = _inputs(cfg, B=2, S=4)
    c1 = model.init_cache(cfg, 2, 8)
    c2 = model.init_cache(cfg, 2, 8)
    for i in range(4):
        l1, c1 = model.decode_step(cfg, params, c1, tokens[:, i:i + 1])
        l2, c2 = model.decode_step(cfg, params, c2, tokens[:, i:i + 1],
                                   unroll=True)
        np.testing.assert_allclose(np.asarray(l1, np.float32),
                                   np.asarray(l2, np.float32),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.slow
def test_block_skip_is_exact():
    """Triangular block skipping must not change attention numerics."""
    cfg = reduced(get_arch("qwen3-1.7b"))
    params = model.init_params(cfg, KEY)
    tokens, labels, _ = _inputs(cfg, B=2, S=64)
    cfg_ns = dataclasses.replace(cfg, block_skip=False)
    a = model.loss_fn(cfg, params, tokens, labels, remat=False, unroll=True,
                      seq_chunk=32)
    b = model.loss_fn(cfg_ns, params, tokens, labels, remat=False,
                      unroll=True, seq_chunk=32)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-6)


@pytest.mark.slow
def test_chunked_ssd_matches_step_scan():
    """§Perf hillclimb 3: the chunkwise-parallel SSD path is numerically
    equivalent to the per-step recurrence."""
    import jax
    from repro.models import ssm
    p = ssm.init_mamba2(jax.random.key(0), 64, head_dim=16, ssm_state=8,
                        dtype=jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 96, 64), jnp.float32)
    y0, (h0, _) = ssm.mamba2_mix(p, x, head_dim=16, ssm_state=8, ssd_chunk=0)
    for c in (16, 32, 96):
        y1, (h1, _) = ssm.mamba2_mix(p, x, head_dim=16, ssm_state=8,
                                     ssd_chunk=c)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(h0), np.asarray(h1),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.slow
def test_moe_decode_global_matches_grouped():
    """§Perf hillclimb 2: global decode dispatch == per-group dispatch
    (single host device: G is 1 either way structurally, but the flag path
    must not change results)."""
    import dataclasses as dc
    cfg = reduced(get_arch("qwen3-moe-235b-a22b"))
    params = model.init_params(cfg, KEY)
    tokens, _, _ = _inputs(cfg, B=2, S=1)
    c1 = model.init_cache(cfg, 2, 4)
    c2 = model.init_cache(cfg, 2, 4)
    l1, _ = model.decode_step(cfg, params, c1, tokens[:, :1])
    cfg2 = dc.replace(cfg, moe_decode_global=False)
    l2, _ = model.decode_step(cfg2, params, c2, tokens[:, :1])
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.slow
def test_loss_decreases_on_overfit():
    cfg = reduced(get_arch("olmo-1b"))
    params = model.init_params(cfg, KEY)
    opt_cfg = adamw.AdamWConfig(lr=5e-3)
    opt_state = adamw.init(params, opt_cfg)
    tokens, labels, _ = _inputs(cfg, B=4, S=32)
    batch = {"tokens": tokens, "labels": labels}
    step = jax.jit(steps_lib.build_train_step(cfg, opt_cfg))
    losses = []
    for _ in range(12):
        params, opt_state, loss = step(params, opt_state, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8, losses


def test_param_count_sane():
    # spec-sheet sanity: kimi ~1T total / ~32B active, qwen3-moe ~235B/22B
    kimi = get_arch("kimi-k2-1t-a32b")
    assert 0.7e12 < kimi.param_count() < 1.4e12
    assert 15e9 < kimi.active_param_count() < 45e9
    q3 = get_arch("qwen3-moe-235b-a22b")
    assert 180e9 < q3.param_count() < 280e9
    assert 12e9 < q3.active_param_count() < 30e9
