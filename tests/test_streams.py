"""Unit tests for the Table I/II stream APIs (core/streams.py)."""
import jax.numpy as jnp
import numpy as np

from repro.core import streams as st


def _mk_words(bits: str) -> jnp.ndarray:
    """LSB-first bitstring -> uint32 word array (padded)."""
    bits = bits + "0" * ((-len(bits)) % 32)
    words = []
    for i in range(0, len(bits), 32):
        w = 0
        for j, b in enumerate(bits[i:i + 32]):
            w |= int(b) << j
        words.append(w)
    return jnp.asarray(words + [0, 0], jnp.uint32)


class TestBitStream:
    def test_fetch_sequence(self):
        s = st.bitstream(_mk_words("10110011" * 8))
        v, s = st.fetch_bits(s, 3)       # bits 101 LSB-first -> 0b101
        assert int(v) == 0b101
        v, s = st.fetch_bits(s, 5)       # bits 10011 -> 0b11001
        assert int(v) == 0b11001
        assert int(s.pos) == 8

    def test_peek_does_not_advance(self):
        s = st.bitstream(_mk_words("1111000010101010"))
        a = st.peek_bits(s, 7)
        b = st.peek_bits(s, 7)
        assert int(a) == int(b)
        assert int(s.pos) == 0

    def test_cross_word_fetch(self):
        # place a known pattern across the 32-bit boundary
        rng = np.random.default_rng(0)
        raw = "".join(rng.choice(["0", "1"], 96))
        s = st.bitstream(_mk_words(raw))
        s = st.skip_bits(s, 27)
        v = st.peek_bits(s, 12)
        expect = int(raw[27:39][::-1], 2)
        assert int(v) == expect

    def test_dynamic_n(self):
        s = st.bitstream(_mk_words("1" * 64))
        v = st.peek_bits(s, jnp.int32(5))
        assert int(v) == 31


class TestByteStream:
    def test_read_value_widths(self):
        data = jnp.asarray(np.arange(12, dtype=np.uint8))
        assert int(st.read_value_at(data, 2, 1)) == 2
        assert int(st.read_value_at(data, 2, 2)) == 2 | (3 << 8)
        assert int(st.read_value_at(data, 0, 4)) == 0x03020100


class TestOutStream:
    def test_write_byte(self):
        s = st.outstream(8, jnp.uint8)
        s = st.write_byte(s, jnp.uint32(7))
        s = st.write_byte(s, jnp.uint32(9))
        assert s.buf[:2].tolist() == [7, 9]
        assert int(s.pos) == 2

    def test_write_run_with_delta(self):
        s = st.outstream(64 + 16, jnp.uint32)
        s = st.write_run(s, jnp.uint32(10), jnp.int32(5), jnp.uint32(3), 16)
        assert s.buf[:5].tolist() == [10, 13, 16, 19, 22]
        assert int(s.pos) == 5

    def test_write_run_wraparound(self):
        # negative delta as two's complement wraps correctly
        s = st.outstream(32, jnp.uint32)
        neg1 = jnp.uint32(0xFFFFFFFF)
        s = st.write_run(s, jnp.uint32(5), jnp.int32(4), neg1, 8)
        assert s.buf[:4].tolist() == [5, 4, 3, 2]

    def test_memcpy_non_overlapping(self):
        s = st.outstream(64, jnp.uint8)
        for b in [1, 2, 3, 4]:
            s = st.write_byte(s, jnp.uint32(b))
        s = st.memcpy(s, jnp.int32(4), jnp.int32(4), 16)
        assert s.buf[:8].tolist() == [1, 2, 3, 4, 1, 2, 3, 4]

    def test_memcpy_overlap_circular(self):
        # the Alg.2 special case: length > offset repeats the window
        s = st.outstream(64, jnp.uint8)
        for b in [7, 8]:
            s = st.write_byte(s, jnp.uint32(b))
        s = st.memcpy(s, jnp.int32(2), jnp.int32(7), 16)
        assert s.buf[:9].tolist() == [7, 8, 7, 8, 7, 8, 7, 8, 7]

    def test_memcpy_offset_one(self):
        # run-of-last-byte via dist=1 (classic deflate idiom)
        s = st.outstream(32, jnp.uint8)
        s = st.write_byte(s, jnp.uint32(42))
        s = st.memcpy(s, jnp.int32(1), jnp.int32(6), 8)
        assert s.buf[:7].tolist() == [42] * 7
