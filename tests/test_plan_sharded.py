"""Mesh-sharded decode executor (ISSUE-5 tentpole, multi-device half).

Runs in subprocesses under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(conftest keeps the main pytest process at 1 device).  Fast tier: each test
is ONE subprocess that batches many assertions — bit-exactness vs
single-device decode for every registered codec (including ragged group
splits, odd tails, and 64-bit planes), checkpoint restore leaves committed
under their requested ``NamedSharding`` with zero ``to_host`` crossings,
sharded token-shard pipelines, and the service's round-robin multi-device
scheduling.
"""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.multidevice   # dedicated CI step (8 CPU devices)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str, ndev: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


def test_sharded_executor_bit_exact_all_codecs():
    """Every registry codec, on an 8-device mesh: execute_sharded ==
    single-device host decode, covering ragged group splits (chunk counts
    not divisible by the device count, single-chunk blobs), odd tails, and
    mixed-geometry fused groups.  Staged steady state re-executes with
    zero transfers in either direction."""
    out = run_py("""
        import numpy as np, jax
        from repro.core import api, registry, transfers
        from repro.core import plan as plan_mod
        from repro.core.engine import CodagEngine, EngineConfig
        from repro.launch import mesh as mesh_lib

        assert len(jax.devices()) == 8
        mesh = mesh_lib.make_decode_mesh()
        eng = CodagEngine(EngineConfig())
        rng = np.random.default_rng(0)

        def demo(name, n, seed=0):
            codec = registry.get(name)
            if n == 0:
                return np.zeros(0, np.uint8 if codec.byte_stream
                                else np.uint32)
            return codec.demo_data(n, np.random.default_rng(seed))[:n]

        for name in registry.names():
            # sizes chosen for ragged splits: single chunk, odd tails,
            # chunk counts that do NOT divide by 8
            cas = [api.compress(demo(name, n, seed=n), name,
                                chunk_bytes=1024)
                   for n in (1, 777, 1025, 4097)]
            host = [api.decompress(ca, eng) for ca in cas]
            outs = api.decompress_many(cas, eng, mesh=mesh)
            for h, o in zip(host, outs):
                o = np.asarray(o)
                assert o.dtype == h.dtype and o.shape == h.shape, name
                assert np.array_equal(o, h), name
            print("OK", name)

        # staged steady state: zero transfers either direction
        blobs = [b for n in ("rle_v2", "bitpack")
                 for b in api.compress(demo(n, 4097, seed=1), n,
                                       chunk_bytes=1024).blobs]
        plan = plan_mod.DecodePlan.build(blobs)
        plan.execute_sharded(mesh, engine=eng)
        with transfers.count_host_transfers() as c, \\
                transfers.no_host_transfers():
            for o in plan.execute_sharded(mesh, engine=eng):
                o.block_until_ready()
        assert c["d2h"] == 0 and c["h2d"] == 0, c
        print("STEADY", c["d2h"], c["h2d"])
        print("PASS")
    """)
    assert "PASS" in out


def test_sharded_64bit_planes_and_block_unit():
    out = run_py("""
        import numpy as np, jax
        from jax.experimental import enable_x64
        from repro.core import api
        from repro.core.engine import CodagEngine, EngineConfig
        from repro.launch import mesh as mesh_lib

        mesh = mesh_lib.make_decode_mesh()
        eng = CodagEngine(EngineConfig())
        rng = np.random.default_rng(3)

        # 64-bit planes: lo/hi u32 blobs share one group; rows split
        # across devices and recombine on device
        for dtype in ("int64", "uint64", "float64"):
            if dtype == "float64":
                arr = np.round(rng.normal(size=1003), 2).astype(np.float64)
            else:
                arr = rng.integers(0, 5000, 1003).astype(dtype)
            ca = api.compress(arr, "rle_v2", chunk_bytes=1024)
            host = api.decompress(ca, eng)
            with enable_x64():
                [dev] = api.decompress_many([ca], eng, mesh=mesh)
                assert str(dev.dtype) == dtype
                assert np.array_equal(np.asarray(dev), host)
            print("OK", dtype)

        # the block (RAPIDS-ablation) provisioning unit shards too:
        # shard_map wraps the same plan dispatch stage
        blk = CodagEngine(EngineConfig(unit="block", n_units=2))
        arr = np.repeat(rng.integers(0, 50, 40).astype(np.uint32), 60)
        ca = api.compress(arr, "rle_v2", chunk_bytes=512)
        [out] = api.decompress_many([ca], blk, mesh=mesh)
        assert np.array_equal(np.asarray(out), arr)
        print("PASS")
    """)
    assert "PASS" in out


def test_sharded_restore_places_leaves(tmp_path):
    """restore(shardings=..., device_out=True): compressed leaves decode
    across the shardings' mesh and come back committed under each leaf's
    requested NamedSharding — with zero to_host funnel crossings."""
    out = run_py(f"""
        import numpy as np, jax
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.core import transfers
        from repro.checkpoint import checkpoint as ckpt

        rng = np.random.default_rng(9)
        state = {{"w": rng.normal(size=(64, 64)).astype(np.float32),
                  "m": rng.integers(0, 200, (128, 32)).astype(np.int32),
                  "small": np.float32(1.5)}}
        ckpt.save("{tmp_path}", 3, state, codec="rle_v2")

        mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2),
                    ("data", "model"))
        shs = {{"w": NamedSharding(mesh, P("data", "model")),
                "m": NamedSharding(mesh, P("data", None)),
                "small": NamedSharding(mesh, P())}}
        with transfers.count_host_transfers() as c:
            out = ckpt.restore("{tmp_path}", 3, state, shardings=shs,
                               device_out=True)
        assert c["d2h"] == 0, c
        for k, v in state.items():
            got = out[k]
            assert got.sharding.is_equivalent_to(shs[k], got.ndim), \\
                (k, got.sharding)
            assert str(got.dtype) == str(np.asarray(v).dtype)
            assert np.array_equal(np.asarray(got), v), k
        print("PASS")
    """)
    assert "PASS" in out


def test_sharded_pipeline_and_service_round_robin():
    out = run_py("""
        import numpy as np, jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import api
        from repro.core.engine import CodagEngine, EngineConfig
        from repro.core.server import DecompressionService
        from repro.data import pipeline as pl
        from repro.launch import mesh as mesh_lib

        mesh = mesh_lib.make_decode_mesh()
        eng = CodagEngine(EngineConfig())

        # token shards born sharded over the data axis, bit-exact
        toks = pl.synthetic_corpus(32768, 500, seed=2)
        store = pl.CompressedTokenStore.build(toks, 500, shard_tokens=8192,
                                              chunk_bytes=2048)
        want_sh = NamedSharding(mesh, P("data"))
        host = list(store.decoded_shards(eng, window=2))
        dev = list(store.decoded_shards(eng, window=2, mesh=mesh))
        assert len(host) == len(dev) >= 2
        for h, d in zip(host, dev):
            assert d.sharding.is_equivalent_to(want_sh, d.ndim), d.sharding
            assert np.array_equal(np.asarray(d), h)
        loader = pl.CompressedLoader(store, batch=2, seq=128, engine=eng,
                                     prefetch=False, mesh=mesh)
        b = next(iter(loader))
        hb = next(iter(pl.CompressedLoader(store, batch=2, seq=128,
                                           engine=eng, prefetch=False)))
        assert np.array_equal(np.asarray(b["tokens"]),
                              np.asarray(hb["tokens"]))
        print("pipeline OK")

        # service: round-robin group->device assignment across all 8
        rng = np.random.default_rng(0)
        arrays = ([np.repeat(rng.integers(0, 50, 20).astype(np.uint32),
                             50 + i) for i in range(4)] +
                  [rng.integers(0, 200, 600 + i).astype(np.uint8)
                   for i in range(4)] +
                  [rng.integers(0, 127, 900 + i).astype(np.uint32)
                   for i in range(4)])
        codecs = ["rle_v2"] * 4 + ["rle_v1"] * 4 + ["bitpack"] * 4
        blobs = [api.compress(a, c, chunk_bytes=512).blobs[0]
                 for a, c in zip(arrays, codecs)]
        with DecompressionService(eng, devices=jax.devices(),
                                  cache_bytes=0, bucket_shapes=False,
                                  max_batch_blobs=4) as svc:
            futs = svc.submit_many(blobs[:4]) + svc.submit_many(blobs[4:8]) \\
                + svc.submit_many(blobs[8:])
            outs = [f.result(timeout=300) for f in futs]
            st = svc.stats()
        for a, o in zip(arrays, outs):
            assert np.array_equal(a, o)
        assert sum(st.device_dispatches.values()) == st.dispatches >= 3
        # round-robin spread: more than one device did work
        assert len(st.device_dispatches) >= 2, st.device_dispatches
        print("service OK", st.device_dispatches)
        print("PASS")
    """)
    assert "PASS" in out
