"""Optimizer + gradient-compression tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw, grad_compress as gc


def _train_quadratic(opt_cfg, steps=60):
    params = {"w": jnp.asarray(np.linspace(-2, 2, 256), jnp.float32)}
    state = adamw.init(params, opt_cfg)
    target = jnp.ones((256,))
    losses = []
    for _ in range(steps):
        g = {"w": 2 * (params["w"] - target)}
        params, state = adamw.apply(params, g, state, opt_cfg)
        losses.append(float(jnp.mean((params["w"] - target) ** 2)))
    return losses


def test_adamw_descends():
    losses = _train_quadratic(adamw.AdamWConfig(lr=5e-2, weight_decay=0.0))
    assert losses[-1] < losses[0] * 0.01


def test_compressed_moments_track_uncompressed():
    base = _train_quadratic(adamw.AdamWConfig(lr=5e-2, weight_decay=0.0))
    comp = _train_quadratic(adamw.AdamWConfig(lr=5e-2, weight_decay=0.0,
                                              compress_moments=True))
    assert comp[-1] < base[0] * 0.05    # still converges
    assert abs(comp[-1] - base[-1]) < 0.1


def test_compressed_moment_memory():
    params = {"w": jnp.zeros((4096,), jnp.bfloat16)}
    s8 = adamw.init(params, adamw.AdamWConfig(compress_moments=True))
    s32 = adamw.init(params, adamw.AdamWConfig())
    b8 = sum(x.nbytes for x in jax.tree.leaves(s8["m"]))
    b32 = sum(x.nbytes for x in jax.tree.leaves(s32["m"]))
    assert b8 < b32 / 3.5               # int8 + scales ~ 4x smaller


def test_int8_quantize_error_bound():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(100 * gc.QBLOCK,)), jnp.float32)
    out = gc.quantize_grads({"g": g})["g"]
    err = np.abs(np.asarray(out - g))
    block_max = np.abs(np.asarray(g)).reshape(-1, gc.QBLOCK).max(1)
    # error bounded by one int8 quantum per block
    assert (err.reshape(-1, gc.QBLOCK).max(1) <= block_max / 127.0 + 1e-7).all()


def test_topk_error_feedback_conserves_value():
    """EF invariant: sum of sent updates + residual == n_rounds * g exactly
    (nothing is lost, only delayed)."""
    g = jnp.asarray(np.linspace(0, 1, 1000), jnp.float32)
    residual = jnp.zeros_like(g)
    sent = jnp.zeros_like(g)
    n = 50
    for _ in range(n):
        s, residual = gc.topk_sparsify(g, residual, frac=0.05)
        sent = sent + s
    np.testing.assert_allclose(np.asarray(sent + residual),
                               np.asarray(n * g), rtol=1e-4, atol=1e-4)
    # the max entry is transmitted (almost) every round
    assert float(sent[-1]) / n > 0.95 * float(g[-1])


def test_topk_wire_accounting():
    assert gc.topk_wire_bytes(1 << 20, 0.01) < (1 << 20) * 4 / 20
