"""Optimizer + gradient-compression tests (incl. the single-device half of
the compressed-collective wire: device encode bit-exactness vs the host
registry encoder, and the wire-faithful grad compressor)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw, grad_compress as gc


def _train_quadratic(opt_cfg, steps=60):
    params = {"w": jnp.asarray(np.linspace(-2, 2, 256), jnp.float32)}
    state = adamw.init(params, opt_cfg)
    target = jnp.ones((256,))
    losses = []
    for _ in range(steps):
        g = {"w": 2 * (params["w"] - target)}
        params, state = adamw.apply(params, g, state, opt_cfg)
        losses.append(float(jnp.mean((params["w"] - target) ** 2)))
    return losses


def test_adamw_descends():
    losses = _train_quadratic(adamw.AdamWConfig(lr=5e-2, weight_decay=0.0))
    assert losses[-1] < losses[0] * 0.01


def test_compressed_moments_track_uncompressed():
    base = _train_quadratic(adamw.AdamWConfig(lr=5e-2, weight_decay=0.0))
    comp = _train_quadratic(adamw.AdamWConfig(lr=5e-2, weight_decay=0.0,
                                              compress_moments=True))
    assert comp[-1] < base[0] * 0.05    # still converges
    assert abs(comp[-1] - base[-1]) < 0.1


def test_compressed_moment_memory():
    params = {"w": jnp.zeros((4096,), jnp.bfloat16)}
    s8 = adamw.init(params, adamw.AdamWConfig(compress_moments=True))
    s32 = adamw.init(params, adamw.AdamWConfig())
    b8 = sum(x.nbytes for x in jax.tree.leaves(s8["m"]))
    b32 = sum(x.nbytes for x in jax.tree.leaves(s32["m"]))
    assert b8 < b32 / 3.5               # int8 + scales ~ 4x smaller


def test_int8_quantize_error_bound():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(100 * gc.QBLOCK,)), jnp.float32)
    out = gc.quantize_grads({"g": g})["g"]
    err = np.abs(np.asarray(out - g))
    block_max = np.abs(np.asarray(g)).reshape(-1, gc.QBLOCK).max(1)
    # error bounded by one int8 quantum per block
    assert (err.reshape(-1, gc.QBLOCK).max(1) <= block_max / 127.0 + 1e-7).all()


def test_topk_error_feedback_conserves_value():
    """EF invariant: sum of sent updates + residual == n_rounds * g exactly
    (nothing is lost, only delayed)."""
    g = jnp.asarray(np.linspace(0, 1, 1000), jnp.float32)
    residual = jnp.zeros_like(g)
    sent = jnp.zeros_like(g)
    n = 50
    for _ in range(n):
        s, residual = gc.topk_sparsify(g, residual, frac=0.05)
        sent = sent + s
    np.testing.assert_allclose(np.asarray(sent + residual),
                               np.asarray(n * g), rtol=1e-4, atol=1e-4)
    # the max entry is transmitted (almost) every round
    assert float(sent[-1]) / n > 0.95 * float(g[-1])


def test_topk_wire_accounting():
    assert gc.topk_wire_bytes(1 << 20, 0.01) < (1 << 20) * 4 / 20


@pytest.mark.parametrize("shape", [(257,), (4, 96), (100 * gc.QBLOCK,)])
def test_quantize_roundtrip_error_bound(shape):
    """quantize_leaf/dequantize_leaf round trip within half an int8
    quantum per block, any leaf geometry (incl. non-multiple-of-128)."""
    rng = np.random.default_rng(7)
    g = rng.standard_normal(shape).astype(np.float32)
    q, s = gc.quantize_leaf(jnp.asarray(g))
    back = np.asarray(gc.dequantize_leaf(q, s, shape))
    flat = np.zeros(q.size, np.float32)
    flat[: g.size] = g.reshape(-1)
    scale = np.asarray(s).reshape(-1)
    err = np.abs(back.reshape(-1) - g.reshape(-1))
    bound = np.repeat(scale, gc.QBLOCK)[: g.size] * 0.5 + 1e-7
    assert (err <= bound).all()


def test_topk_select_exact_k_on_ties():
    """Tied magnitudes (the quantized-grads case) must not blow past k —
    the wire-bytes estimate is exact only if EXACTLY k entries survive."""
    flat = jnp.asarray(np.tile([0.5, -0.5], 500).astype(np.float32))
    for k in (1, 7, 100):
        mask, kept = gc.topk_select(flat, k)
        assert int(mask.sum()) == k
        assert int((kept != 0).sum()) == k
    # deterministic: same input -> same mask
    m1, _ = gc.topk_select(flat, 13)
    m2, _ = gc.topk_select(flat, 13)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
    # topk_sparsify inherits the exact-k guarantee
    g = jnp.asarray(np.full(1000, 0.25, np.float32))
    sparse, _ = gc.topk_sparsify(g, jnp.zeros_like(g), frac=0.01)
    k = max(1, int(g.size * 0.01))
    assert int((sparse != 0).sum()) == k
    # ... so the wire estimate matches the actual mask payload
    assert gc.topk_wire_bytes(g.size, 0.01) == k * 2.0 + g.size / 8.0


# ---------------------------------------------------------------------------
# collective wire formats (single-device half; the shard_map half lives in
# tests/test_distributed.py)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits,chunk_elems", [(8, gc.QBLOCK), (1, 2048),
                                              (4, 256)])
def test_device_wire_bit_exact_vs_host_encoder(bits, chunk_elems):
    """pack_bits_rows + wire_dev build the bitpack codec's EXACT wire
    layout on device: every table the collective all-gathers is byte-for-
    byte a registry blob (comp, comp_words, lens, shared extras)."""
    from repro.core import encoders
    from repro.distributed import collectives as C
    from repro.kernels import ops

    rng = np.random.default_rng(bits)
    n_chunks = 5
    vals = rng.integers(0, 1 << bits, (n_chunks, chunk_elems)).astype(
        np.uint32)
    dev = C.wire_dev(C.pack_bits_rows(jnp.asarray(vals), bits),
                     chunk_elems=chunk_elems, bits=bits)
    blob = encoders.compress(vals.reshape(-1).astype(np.uint8), "bitpack",
                             chunk_bytes=chunk_elems, bits=bits)
    host_dev, static_bits = ops.table_inputs(blob)
    assert static_bits == bits
    assert sorted(host_dev) == sorted(dev)
    for k in host_dev:
        np.testing.assert_array_equal(np.asarray(host_dev[k]),
                                      np.asarray(dev[k]), err_msg=k)


def test_wire_compressor_matches_quantize_grads():
    """The wire-faithful compressor (encode -> plan.dispatch decode with
    fused dequant epilogue) is numerically identical to the reference
    quantize->dequantize pass, and works under jit."""
    from repro.distributed import collectives as C

    rng = np.random.default_rng(11)
    grads = {"w": jnp.asarray(rng.standard_normal((700,)), jnp.float32),
             "b": jnp.asarray(rng.standard_normal((7,)), jnp.float32),
             "m": jnp.asarray(rng.standard_normal((3, 129)), jnp.float32)}
    comp = C.make_wire_compressor()
    got = comp(grads)
    want = gc.quantize_grads(grads)
    for k in grads:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(want[k]), err_msg=k)
        assert got[k].shape == grads[k].shape
    got_jit = jax.jit(comp)(grads)
    for k in grads:
        # jit may fuse the scale arithmetic differently (fma) — allow
        # one-ulp-scale drift, nothing structural
        np.testing.assert_allclose(np.asarray(got_jit[k]),
                                   np.asarray(got[k]), rtol=1e-6,
                                   atol=1e-6, err_msg=k)
