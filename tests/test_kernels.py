"""Per-kernel validation: Pallas (interpret mode) vs the pure-jnp oracle,
sweeping shapes and dtypes, exactly as the kernel contract requires."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import encoders as enc, format as fmt, registry
from repro.kernels import bitpack, ops, ref

RNG = np.random.default_rng(3)


def _gen(kind: str, n: int, dtype):
    info = np.iinfo(dtype)
    if kind == "runs":
        v = RNG.integers(0, min(50, info.max), max(1, n // 20)).astype(dtype)
        out = np.repeat(v, RNG.integers(1, 40, len(v)))
    elif kind == "random":
        out = RNG.integers(0, info.max, n, endpoint=True).astype(dtype)
    elif kind == "delta":
        out = (np.arange(n) * 5 + 11).astype(dtype)
    else:  # mixed
        out = np.concatenate([
            np.repeat(dtype(3), n // 3),
            RNG.integers(0, info.max, n // 3, endpoint=True).astype(dtype),
            (np.arange(n - 2 * (n // 3)) * 2).astype(dtype)])
    return out[:n] if len(out) >= n else np.pad(out, (0, n - len(out)))


def _decode_both(blob: fmt.CompressedBlob, codec):
    dev = {k: jnp.asarray(v) for k, v in blob.to_device().items()}
    bits = registry.get(codec).static_bits(blob)
    pallas_out = ops.decode(dev, codec=codec, width=blob.width,
                            chunk_elems=blob.chunk_elems, backend="pallas",
                            interpret=True, bits=bits)
    oracle_out = ops.decode(dev, codec=codec, width=blob.width,
                            chunk_elems=blob.chunk_elems, backend="oracle",
                            bits=bits)
    return np.asarray(pallas_out), np.asarray(oracle_out), blob


@pytest.mark.parametrize("codec", [fmt.RLE_V1, fmt.RLE_V2, fmt.DBP])
@pytest.mark.parametrize("dtype", [np.uint8, np.uint16, np.uint32])
@pytest.mark.parametrize("kind", ["runs", "random", "delta", "mixed"])
@pytest.mark.parametrize("n,chunk_bytes", [
    (257, 256),
    pytest.param(1024, 512, marks=pytest.mark.slow),
    pytest.param(4096, 2048, marks=pytest.mark.slow)])
def test_two_phase_kernel_vs_oracle(codec, dtype, kind, n, chunk_bytes):
    """Two-phase harness codecs: Pallas (interpret) vs sequential oracle."""
    arr = _gen(kind, n, dtype)
    blob = enc.compress(arr, codec, chunk_bytes=chunk_bytes)
    got_pallas, got_oracle, blob = _decode_both(blob, codec)
    # valid region comparison per chunk (tail of last chunk is padding)
    for i in range(blob.num_chunks):
        ol = int(blob.out_lens[i])
        np.testing.assert_array_equal(got_pallas[i, :ol], got_oracle[i, :ol],
                                      err_msg=f"chunk {i}")
    flat = got_pallas.reshape(-1)[:blob.total_elems]
    np.testing.assert_array_equal(flat.astype(dtype), arr.view(dtype))


@pytest.mark.parametrize("kind", ["runs", "random", "mixed"])
@pytest.mark.parametrize("n,chunk_bytes", [
    (700, 512), pytest.param(3000, 1024, marks=pytest.mark.slow)])
def test_tdeflate_kernel_vs_oracle(kind, n, chunk_bytes):
    arr = _gen(kind, n, np.uint8)
    blob = enc.compress(arr, fmt.TDEFLATE, chunk_bytes=chunk_bytes)
    got_pallas, got_oracle, blob = _decode_both(blob, fmt.TDEFLATE)
    for i in range(blob.num_chunks):
        ol = int(blob.out_lens[i])
        np.testing.assert_array_equal(got_pallas[i, :ol], got_oracle[i, :ol])
    flat = got_pallas.reshape(-1)[:blob.total_elems]
    np.testing.assert_array_equal(flat, arr)


@pytest.mark.parametrize("bits", [1, 3, 7, 8, 13, 16, 24, 32])
@pytest.mark.parametrize("n", [100, 2048,
                               pytest.param(5000, marks=pytest.mark.slow)])
def test_bitpack_kernel_vs_oracle(bits, n):
    maxv = (1 << bits) - 1 if bits < 32 else 2 ** 32 - 1
    arr = RNG.integers(0, maxv, n, endpoint=True).astype(np.uint32)
    words = enc.pack_bits(arr.astype(np.uint64), bits)
    wj = jnp.asarray(np.concatenate([words, np.zeros(2, np.uint32)]))
    got_k = bitpack.unpack_pallas(wj[None], bits=bits, out_elems=n,
                                  interpret=True)[0]
    got_o = ref.unpack_bits(wj, n, bits)
    np.testing.assert_array_equal(np.asarray(got_k), np.asarray(got_o))
    np.testing.assert_array_equal(np.asarray(got_o), arr)


def test_scalar_variant_matches_vectorized():
    """§V-E ablation implementations agree with the two-phase kernels."""
    for codec in (fmt.RLE_V1, fmt.RLE_V2, fmt.DBP):
        arr = _gen("mixed", 2000, np.uint16)
        blob = enc.compress(arr, codec, chunk_bytes=777)
        dev = {k: jnp.asarray(v) for k, v in blob.to_device().items()}
        a = ops.decode(dev, codec=codec, width=blob.width,
                       chunk_elems=blob.chunk_elems, backend="xla")
        b = ops.decode(dev, codec=codec, width=blob.width,
                       chunk_elems=blob.chunk_elems, backend="scalar")
        for i in range(blob.num_chunks):
            ol = int(blob.out_lens[i])
            np.testing.assert_array_equal(np.asarray(a)[i, :ol],
                                          np.asarray(b)[i, :ol])


def test_tdeflate_scalar_matches():
    arr = _gen("mixed", 1500, np.uint8)
    blob = enc.compress(arr, fmt.TDEFLATE, chunk_bytes=600)
    dev = {k: jnp.asarray(v) for k, v in blob.to_device().items()}
    a = ops.decode(dev, codec=fmt.TDEFLATE, width=1,
                   chunk_elems=blob.chunk_elems, backend="xla")
    b = ops.decode(dev, codec=fmt.TDEFLATE, width=1,
                   chunk_elems=blob.chunk_elems, backend="scalar")
    for i in range(blob.num_chunks):
        ol = int(blob.out_lens[i])
        np.testing.assert_array_equal(np.asarray(a)[i, :ol],
                                      np.asarray(b)[i, :ol])


@pytest.mark.parametrize("M,K,N", [(128, 128, 128), (256, 384, 256),
                                   (128, 512, 384)])
@pytest.mark.parametrize("dtype", [np.float32])
def test_dequant_matmul_kernel(M, K, N, dtype):
    """Fused int8-dequant matmul (hillclimb 2 hot spot) vs oracle."""
    from repro.kernels import dequant_matmul as dq
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.normal(size=(M, K)).astype(dtype))
    q = jnp.asarray(rng.integers(-127, 127, (K, N)).astype(np.int8))
    s = jnp.asarray(np.abs(rng.normal(size=(1, N))).astype(np.float32) * 0.01)
    got = dq.dequant_matmul(x, q, s, interpret=True)
    want = dq.ref_dequant_matmul(x, q, s)
    # split-K accumulation order differs from the single-sum oracle
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-3, atol=1e-4)
