"""Codec-plugin framework: registry completeness + ops-layer satellites.

Covers:
  * the registry-completeness contract CI gates on (every registered codec
    has full hooks and appears in the bench-smoke + ablation matrices),
  * the reentrant ``ops.count_dispatches`` (nested contexts),
  * the ``ops.words_view`` zero-padding fix for odd-width rows.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api, encoders as enc, format as fmt, registry
from repro.kernels import ops

RNG = np.random.default_rng(23)


# --------------------------------------------------------------------------
# registry completeness
# --------------------------------------------------------------------------


def test_registry_covers_builtin_codecs():
    # superset, not equality: third-party plugins may register extra codecs
    assert set(fmt.CODECS) <= set(registry.names())


@pytest.mark.parametrize("name", registry.names())
def test_registered_codec_is_complete(name):
    """Every codec declares the full plugin surface the system relies on."""
    codec = registry.get(name)
    assert codec.name == name
    assert callable(codec.encode)
    spec = codec.decode
    assert callable(spec.body)
    # demo_data drives the bench matrices and the batch-coverage test
    assert codec.demo_data is not None
    arr = codec.demo_data(512, RNG)
    assert isinstance(arr, np.ndarray) and arr.size == 512
    # the declared hooks actually round-trip
    ca = api.compress(arr, name, chunk_bytes=777)
    assert np.array_equal(api.decompress(ca), arr)


def test_bench_smoke_matrices_cover_registry():
    """CI gate: a registered codec missing from the bench-smoke or ablation
    matrix fails here (and in scripts/check_registry.py)."""
    from benchmarks import ablations, batched
    assert set(batched.codec_matrix()) == set(registry.names())
    assert set(ablations.codec_matrix()) == set(registry.names())


def test_unknown_codec_raises():
    with pytest.raises(ValueError, match="unknown codec"):
        registry.get("no_such_codec")
    with pytest.raises(ValueError, match="unknown codec"):
        enc.compress(np.zeros(4, np.uint32), "no_such_codec")


def test_group_key_uses_registry_static_bits():
    b9 = enc.compress(RNG.integers(0, 2 ** 9, 256).astype(np.uint32),
                      fmt.BITPACK, 512, bits=9)
    b7 = enc.compress(RNG.integers(0, 2 ** 7, 256).astype(np.uint32),
                      fmt.BITPACK, 512, bits=7)
    assert fmt.group_key(b9) != fmt.group_key(b7)
    d = enc.compress(RNG.integers(0, 99, 256).astype(np.uint32), fmt.DBP, 512)
    assert fmt.group_key(d) == (fmt.DBP, 4, 128, 0)


# --------------------------------------------------------------------------
# ops.count_dispatches reentrancy (satellite)
# --------------------------------------------------------------------------


def _decode_once():
    blob = enc.compress(np.repeat(np.uint32(5), 600), fmt.RLE_V1, 512)
    return ops.decode_table(blob)


def test_count_dispatches_nested():
    """Nested contexts each see their own window of dispatches, and exiting
    the inner one must not disconnect (or clobber) the outer one."""
    with ops.count_dispatches() as outer:
        _decode_once()
        with ops.count_dispatches() as inner:
            _decode_once()
        assert len(inner) == 1
        _decode_once()          # after inner exit: outer still counting
    assert len(outer) == 3
    assert len(inner) == 1
    # fully unwound: no observer leaks into subsequent dispatches
    _decode_once()
    assert len(outer) == 3


def test_count_dispatches_nested_equal_contents():
    """Immediately-nested contexts hold equal-valued lists; the inner exit
    must detach ITS list (identity, not value equality), and the outer exit
    must not raise."""
    with ops.count_dispatches() as outer:
        with ops.count_dispatches() as inner:
            _decode_once()      # both lists now equal: [rec]
        _decode_once()          # must land in outer only
    assert len(inner) == 1
    assert len(outer) == 2


def test_count_dispatches_overlapping_exit_order():
    """Out-of-LIFO exits (e.g. via ExitStack misuse) stay consistent."""
    c1 = ops.count_dispatches()
    c2 = ops.count_dispatches()
    l1 = c1.__enter__()
    l2 = c2.__enter__()
    _decode_once()
    c1.__exit__(None, None, None)       # close the OUTER first
    _decode_once()
    c2.__exit__(None, None, None)
    assert len(l1) == 1
    assert len(l2) == 2


# --------------------------------------------------------------------------
# ops.words_view odd-width zero-padding (satellite)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("width_bytes", [5, 6, 7, 9, 333])
def test_words_view_pads_odd_row_widths(width_bytes):
    comp = RNG.integers(0, 255, (3, width_bytes)).astype(np.uint8)
    words = np.asarray(ops.words_view(jnp.asarray(comp)))
    padded = np.zeros((3, -(-width_bytes // 4) * 4), np.uint8)
    padded[:, :width_bytes] = comp
    expect = padded.view("<u4")
    np.testing.assert_array_equal(words, expect)


def test_words_view_on_oddly_padded_blob():
    """Regression: a blob whose host comp table has a non-multiple-of-4 row
    width must decode through the word view, not fail in reshape."""
    arr = np.frombuffer(b"abcabcabc" * 37, np.uint8).copy()
    blob = enc.compress(arr, fmt.TDEFLATE, 512)
    if blob.comp.shape[1] % 4 == 0:    # force an odd row width
        blob.comp = np.pad(blob.comp, ((0, 0), (0, 1)))
    assert blob.comp.shape[1] % 4 != 0
    dev = {"comp": jnp.asarray(blob.comp),
           "comp_lens": jnp.asarray(blob.comp_lens),
           "out_lens": jnp.asarray(blob.out_lens)}
    dev.update({k: jnp.asarray(v) for k, v in blob.extras.items()})
    # no comp_words in the pytree -> the words_view fallback path runs
    out = ops.decode(dev, codec=fmt.TDEFLATE, width=blob.width,
                     chunk_elems=blob.chunk_elems)
    flat = np.asarray(out).reshape(-1)[:blob.total_elems]
    np.testing.assert_array_equal(flat, arr)
