"""The unified DecodePlan IR (ISSUE-5 tentpole, single-device half).

Covers the lowering gate (no ``ops.decode`` call site outside the plan
executor; every dispatch from every entry path originates in
``plan.dispatch``), the digest-keyed epilogue-operand staging cache, the
``blob_digest`` / ``pad_table_to_bucket`` move into ``core.format``, and
the service's round-robin device accounting (single-device degenerate
case — the true multi-device behavior runs in ``test_plan_sharded.py``).
"""
import ast
import inspect

import jax
import numpy as np
import pytest

from repro.core import api, batch, format as fmt, server, transfers
from repro.core import engine as engine_mod
from repro.core import plan as plan_mod
from repro.core.engine import CodagEngine, EngineConfig
from repro.kernels import ops
from repro.kernels.harness import Epilogue

ENGINE = CodagEngine(EngineConfig())
RNG = np.random.default_rng(21)


def _runs(n, seed=0):
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, 90, max(4, n // 40)).astype(np.uint32)
    return np.repeat(vals, rng.integers(1, 80, len(vals)))[:n]


# --------------------------------------------------------------------------
# the lowering gate
# --------------------------------------------------------------------------


def _ops_decode_calls(module):
    """AST walk: calls to ops.decode / ops.decode_table* in a module."""
    tree = ast.parse(inspect.getsource(module))
    hits = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if (isinstance(f, ast.Attribute)
                and f.attr in ("decode", "decode_table",
                               "decode_table_device", "decode_blob")
                and isinstance(f.value, ast.Name)
                and f.value.id == "ops"):
            hits.append(f"{module.__name__}:{node.lineno}")
    return hits


def test_no_ops_decode_call_sites_outside_plan():
    """ISSUE-5 acceptance gate: engine/batch/api/server contain ZERO
    ``ops.decode*`` call sites — the plan executor is the only module that
    lowers to the kernel dispatch layer."""
    for mod in (engine_mod, batch, api, server):
        assert _ops_decode_calls(mod) == [], mod.__name__
    # and plan.py itself still has them (the gate is not vacuous)
    assert _ops_decode_calls(plan_mod)


@pytest.mark.parametrize("entry", ["api_many", "api_one", "engine_host",
                                   "engine_device", "batch_plan", "service"])
def test_every_entry_path_lowers_through_plan(entry):
    """Each public decode entry path's kernel dispatches all originate in
    ``plan.dispatch`` — equal ``count_lowered`` / ``count_dispatches``."""
    # unique total_elems per entry: device-path executors record at trace
    # time only, so each case must miss the jit cache to count dispatches
    arr = _runs(900 + 7 * len(entry), seed=3)
    ca = api.compress(arr, fmt.RLE_V2, chunk_bytes=512)
    with plan_mod.count_lowered() as lowered, \
            ops.count_dispatches() as dispatched:
        if entry == "api_many":
            [out] = api.decompress_many([ca], ENGINE)
        elif entry == "api_one":
            out = api.decompress(ca, ENGINE)
        elif entry == "engine_host":
            out = ENGINE.decompress(ca.blobs[0])
        elif entry == "engine_device":
            out = np.asarray(ENGINE.decompress_device(ca.blobs[0]))
        elif entry == "batch_plan":
            out = batch.BatchPlan.build(ca.blobs).execute(ENGINE)[0]
        else:
            with server.DecompressionService(ENGINE) as svc:
                out = svc.decode(ca.blobs[0])
    assert np.array_equal(np.asarray(out).reshape(arr.shape), arr)
    assert len(dispatched) >= 1
    assert len(lowered) == len(dispatched)
    assert [c["codec"] for c in lowered] == \
           [c["codec"] for c in dispatched]


def test_block_unit_lowering_matches_warp():
    """The block (RAPIDS-ablation) provisioning unit lives in the plan's
    dispatch stage now — one lowered dispatch, bit-exact output."""
    arr = _runs(3000, seed=5)
    ca = api.compress(arr, fmt.RLE_V2, chunk_bytes=512)
    block = CodagEngine(EngineConfig(unit="block", n_units=3))
    with plan_mod.count_lowered() as lowered:
        out = api.decompress(ca, block)
    assert np.array_equal(out, arr)
    assert len(lowered) == 1 and lowered[0]["unit"] == "block"


def test_batchplan_is_decodeplan_alias():
    """The batch scheduler's machinery lives in exactly one module."""
    assert batch.BatchPlan is plan_mod.DecodePlan
    assert batch.GroupPlan is plan_mod.PlanGroup
    assert batch.decompress_blobs is plan_mod.decompress_blobs


# --------------------------------------------------------------------------
# satellite: digest-keyed epilogue-operand staging cache
# --------------------------------------------------------------------------


def test_operand_cache_alternating_dicts_transfer_free():
    """Regression (ISSUE-5 satellite): the old single-slot identity cache
    re-uploaded operands every call when a consumer alternated between two
    operand dicts.  The digest-keyed cache stages each distinct content
    once — zero host→device transfers afterward, even through fresh dict
    objects."""
    arr = RNG.integers(0, 127, 1500).astype(np.uint32)
    ca = api.compress(arr, fmt.BITPACK, chunk_bytes=1024)
    plan = plan_mod.DecodePlan.build(ca.blobs).stage()
    epi = Epilogue(scale_key="epi_s", zero_key="epi_z")
    op_a = {"epi_s": np.float32(0.25), "epi_z": np.uint32(3)}
    op_b = {"epi_s": np.float32(0.5), "epi_z": np.uint32(1)}
    for op in (op_a, op_b):         # warm both contents (and compile)
        plan.execute_device(ENGINE, epilogue=epi, epilogue_operands=op)
    with transfers.count_host_transfers() as c:
        for _ in range(3):          # alternate via FRESH dict objects
            a = plan.execute_device(ENGINE, epilogue=epi,
                                    epilogue_operands=dict(op_a))[0]
            b = plan.execute_device(ENGINE, epilogue=epi,
                                    epilogue_operands=dict(op_b))[0]
    assert c["h2d"] == 0, c
    np.testing.assert_allclose(np.asarray(a),
                               (arr.astype(np.float32) - 3) * 0.25, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(b),
                               (arr.astype(np.float32) - 1) * 0.5, rtol=1e-6)


def test_operand_cache_device_arrays_key_by_identity():
    """Operands already on device must NOT be content-hashed (hashing a
    jax array forces an implicit d2h sync that bypasses the funnel and
    trips the transfer guard on real accelerators) — they key by identity,
    and the cache holds a strong ref so the id stays valid."""
    import jax.numpy as jnp
    arr = RNG.integers(0, 127, 800).astype(np.uint32)
    ca = api.compress(arr, fmt.BITPACK, chunk_bytes=1024)
    plan = plan_mod.DecodePlan.build(ca.blobs).stage()
    epi = Epilogue(scale_key="epi_s")
    ops_dev = {"epi_s": jnp.float32(0.125)}          # device-resident
    plan.execute_device(ENGINE, epilogue=epi, epilogue_operands=ops_dev)
    assert len(plan._staged_operands) == 1
    with transfers.count_host_transfers() as c, transfers.no_host_transfers():
        out = plan.execute_device(ENGINE, epilogue=epi,
                                  epilogue_operands=ops_dev)[0]
        out.block_until_ready()
    assert c["h2d"] == 0 and c["d2h"] == 0
    assert len(plan._staged_operands) == 1           # identity hit, no growth
    np.testing.assert_allclose(np.asarray(out),
                               arr.astype(np.float32) * 0.125, rtol=1e-6)


def test_operand_cache_bounded():
    """The staging cache is an LRU bounded to OPERAND_CACHE_SLOTS."""
    arr = RNG.integers(0, 127, 600).astype(np.uint32)
    ca = api.compress(arr, fmt.BITPACK, chunk_bytes=1024)
    plan = plan_mod.DecodePlan.build(ca.blobs).stage()
    epi = Epilogue(scale_key="epi_s")
    for i in range(plan_mod.OPERAND_CACHE_SLOTS + 5):
        plan.execute_device(ENGINE, epilogue=epi,
                            epilogue_operands={"epi_s": np.float32(i + 1)})
    assert len(plan._staged_operands) == plan_mod.OPERAND_CACHE_SLOTS


# --------------------------------------------------------------------------
# satellite: blob_digest / pad_table_to_bucket live in core.format
# --------------------------------------------------------------------------


def test_digest_and_bucket_moved_to_format():
    """One definition each; server re-exports the same objects."""
    assert server.blob_digest is fmt.blob_digest
    assert server.pad_table_to_bucket is fmt.pad_table_to_bucket
    blob = api.compress(_runs(700), fmt.RLE_V2, chunk_bytes=512).blobs[0]
    assert fmt.blob_digest(blob) == server.blob_digest(blob)


def test_pad_table_rows_decodes_bit_exact():
    """The shared row-padding helper (bucketing + per-device uniform
    padding both use it): padded tables decode the real rows unchanged."""
    blobs = [api.compress(_runs(700, seed=60 + i), fmt.RLE_V2,
                          chunk_bytes=512).blobs[0] for i in range(3)]
    merged = fmt.concat_blobs(blobs)
    padded = fmt.pad_table_rows(merged, merged.num_chunks + 5)
    assert padded.num_chunks == merged.num_chunks + 5
    table = ENGINE.decompress_table(padded)
    np.testing.assert_array_equal(table[:merged.num_chunks],
                                  ENGINE.decompress_table(merged))
    assert not table[merged.num_chunks:].any()   # pad rows decode to zeros
    with pytest.raises(ValueError, match="pad"):
        fmt.pad_table_rows(merged, merged.num_chunks - 1)


def test_bucketed_plan_build():
    """Plan-level bucketing (the service window path) pads to pow2 rows
    without disturbing per-blob row ranges."""
    blobs = [api.compress(_runs(900, seed=i), fmt.RLE_V2,
                          chunk_bytes=512).blobs[0] for i in range(3)]
    plan = plan_mod.DecodePlan.build(blobs, bucket=True)
    (g,) = plan.groups
    assert g.merged.num_chunks & (g.merged.num_chunks - 1) == 0    # pow2
    for blob, out in zip(blobs, plan.execute(ENGINE)):
        assert np.array_equal(out, ENGINE.decompress(blob))


# --------------------------------------------------------------------------
# place stage + service device accounting (single-device degenerate cases)
# --------------------------------------------------------------------------


def test_place_stage_single_device_sharding():
    """Outputs are committed under a caller-supplied sharding (the place
    stage) — degenerate 1-device mesh in the fast in-process tier."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    sh = NamedSharding(mesh, P("data"))
    arr = _runs(2048, seed=9)[:2048]
    ca = api.compress(arr, fmt.RLE_V2, chunk_bytes=1024)
    [out] = api.decompress_many([ca], ENGINE, device_out=True,
                                out_shardings=sh)
    assert out.sharding.is_equivalent_to(sh, out.ndim)
    assert np.array_equal(np.asarray(out), arr)


def test_placeable_divisibility():
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    sh = NamedSharding(mesh, P("data"))
    assert plan_mod.placeable((8,), sh)
    assert not plan_mod.placeable((), sh)          # spec longer than rank
    sh2 = NamedSharding(mesh, P(None, "data"))
    assert plan_mod.placeable((3, 7), sh2)         # 1-device axis divides


def test_service_round_robin_single_device_accounting():
    """ServiceStats.device_dispatches: with an explicit device list every
    fused dispatch is attributed to its assigned device (true round-robin
    spread is exercised on the 8-device mesh in test_plan_sharded.py)."""
    dev = jax.devices()[0]
    arrays = [_runs(700, seed=70 + i) for i in range(3)]
    arrays.append(RNG.integers(0, 200, 500).astype(np.uint8))
    blobs = [api.compress(a, fmt.RLE_V1, chunk_bytes=512).blobs[0]
             for a in arrays]
    with server.DecompressionService(ENGINE, devices=[dev],
                                     cache_bytes=0,
                                     bucket_shapes=False) as svc:
        futs = svc.submit_many(blobs)
        outs = [f.result(timeout=120) for f in futs]
        st = svc.stats()
    for a, o in zip(arrays, outs):
        assert np.array_equal(a, o)
    assert st.device_dispatches == {str(dev): st.dispatches}
    assert st.dispatches == 2                      # u32 group + u8 group


def test_service_without_devices_has_empty_accounting():
    blob = api.compress(_runs(400), fmt.RLE_V2, chunk_bytes=512).blobs[0]
    with server.DecompressionService(ENGINE) as svc:
        svc.decode(blob)
        assert svc.stats().device_dispatches == {}
