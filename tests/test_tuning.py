"""core.tuning: tuned-defaults resolution, overrides, pipelined wrapper,
and the persistent compile cache.

The invariants that keep the autotuner safe to ship:

  * unknown device kinds / missing table levels fall back to the
    hand-picked constants (the table can never brick a new device);
  * explicit kwargs beat tuned defaults at every layer that consults the
    table (api.compress chunk geometry, pad_table_to_bucket floor,
    EngineConfig.tune kernel knobs);
  * the committed table covers every registered codec with only known
    knob names (mirrors the scripts/check_registry.py gate);
  * the pipelined generic Pallas wrapper (num_stages > 1) stays bit-exact
    vs the XLA reference, including the row-padding remainder path;
  * enable_compile_cache makes a second process's backend compile a disk
    load (checked across real subprocess boundaries).
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.core import api, format as fmt, registry, tuning
from repro.core.engine import CodagEngine, EngineConfig

RNG = np.random.default_rng(3)
ROOT = Path(__file__).resolve().parent.parent


def _table(codec="rle_v2", width=4, kind=None, **knobs):
    kind = kind if kind is not None else tuning.device_kind()
    return {"version": tuning.TABLE_VERSION,
            "codecs": {codec: {f"w{width}": {kind: dict(knobs)}}}}


# --------------------------------------------------------------------------
# lookup semantics
# --------------------------------------------------------------------------


def test_unknown_device_kind_falls_back_to_constants():
    with tuning.override(_table(chunk_bytes=4096, kind="cpu")):
        assert tuning.lookup("rle_v2", 4, "tpu-v99") == {}
        assert tuning.chunk_bytes_for("rle_v2", 4, "tpu-v99") is None
        assert tuning.bucket_cols_floor("rle_v2", 4, "tpu-v99") is None


def test_missing_levels_fall_back():
    with tuning.override(_table(chunk_bytes=4096)):
        assert tuning.lookup("nope", 4) == {}          # unknown codec
        assert tuning.lookup("rle_v2", 2) == {}        # unknown width
    with tuning.override({"version": 1, "codecs": {"rle_v2": {}}}):
        assert tuning.lookup("rle_v2", 4) == {}        # explicit {} fallback


def test_lookup_strips_provenance_keys():
    with tuning.override(_table(chunk_bytes=8192, _tuned_MBps=123.4)):
        assert tuning.lookup("rle_v2", 4) == {"chunk_bytes": 8192}


def test_device_kind_normalization():
    assert tuning.normalize_kind("TPU v4") == "tpu-v4"
    with tuning.override(_table(chunk_bytes=4096, kind="tpu-v4")):
        assert tuning.lookup("rle_v2", 4, "TPU v4") == {"chunk_bytes": 4096}


def test_merge_tables_preserves_other_device_kinds():
    base = _table(chunk_bytes=1024, kind="tpu-v4")
    new = _table(chunk_bytes=4096, kind="cpu")
    merged = tuning.merge_tables(base, new)
    kinds = merged["codecs"]["rle_v2"]["w4"]
    assert kinds["tpu-v4"] == {"chunk_bytes": 1024}
    assert kinds["cpu"] == {"chunk_bytes": 4096}


def test_load_table_version_mismatch_raises(tmp_path):
    p = tmp_path / "t.json"
    p.write_text(json.dumps({"version": 99, "codecs": {}}))
    with pytest.raises(ValueError, match="version"):
        tuning.load_table(p)
    assert tuning.load_table(tmp_path / "missing.json") == tuning.empty_table()


# --------------------------------------------------------------------------
# explicit kwargs beat tuned defaults at every consulting layer
# --------------------------------------------------------------------------


def test_compress_consults_table_and_explicit_wins():
    arr = np.repeat(RNG.integers(0, 9, 40), 50).astype(np.uint32)
    with tuning.override(_table(chunk_bytes=4096)):
        tuned = api.compress(arr, "rle_v2")
        assert tuned.blobs[0].chunk_elems == 4096 // 4
        explicit = api.compress(arr, "rle_v2", chunk_bytes=8192)
        assert explicit.blobs[0].chunk_elems == 8192 // 4
    with tuning.override(None):   # no table at all -> hand-picked default
        default = api.compress(arr, "rle_v2")
        assert default.blobs[0].chunk_elems == fmt.DEFAULT_CHUNK_BYTES // 4


def test_bucket_floor_default_unchanged_without_entry():
    # regression guard: with no tuned entry the pow2 bucketing floor must
    # stay exactly the hand-picked 128 columns
    arr = np.repeat(RNG.integers(0, 9, 30), 40).astype(np.uint32)
    blob = api.compress(arr, "rle_v2", chunk_bytes=1024).blobs[0]
    with tuning.override(None):
        assert fmt.pad_table_to_bucket(blob).comp.shape[1] == 128


def test_bucket_floor_tuned_and_explicit():
    arr = np.repeat(RNG.integers(0, 9, 30), 40).astype(np.uint32)
    blob = api.compress(arr, "rle_v2", chunk_bytes=1024).blobs[0]
    with tuning.override(_table(bucket_cols_floor=512)):
        assert fmt.pad_table_to_bucket(blob).comp.shape[1] == 512
        # explicit floor wins over the tuned entry
        assert fmt.pad_table_to_bucket(blob, cols_floor=256).comp.shape[1] == 256


def test_kernel_tune_merges_and_explicit_wins():
    with tuning.override(_table(chunk_bytes=4096, num_stages=4)):
        # host knobs never leak into the kernel tune tuple
        assert tuning.kernel_tune("rle_v2", 4) == (("num_stages", 4),)
        # EngineConfig.tune-style explicit override wins per knob
        assert tuning.kernel_tune(
            "rle_v2", 4, (("num_stages", 2),)) == (("num_stages", 2),)
    with tuning.override(None):
        assert tuning.kernel_tune("rle_v2", 4) == ()


def test_tuned_defaults_decode_end_to_end():
    # a tuned chunk_bytes must flow compress -> plan -> decode bit-exactly
    arr = np.repeat(RNG.integers(0, 50, 60), RNG.integers(1, 80, 60)) \
        .astype(np.uint32)
    engine = CodagEngine(EngineConfig())
    with tuning.override(_table(chunk_bytes=4096)):
        ca = api.compress(arr, "rle_v2")
        assert ca.blobs[0].chunk_elems == 1024
        np.testing.assert_array_equal(api.decompress(ca, engine), arr)


# --------------------------------------------------------------------------
# committed table coverage (mirrors the check_registry gate)
# --------------------------------------------------------------------------


def test_committed_table_covers_registry():
    table = tuning.load_table()
    codecs = table.get("codecs", {})
    for name in registry.names():
        assert name in codecs, f"{name} missing from tuned_defaults.json"
        allowed = set(tuning.KNOWN_KNOBS) | {
            t.name for t in getattr(registry.get(name).decode, "tunables", ())}
        for kinds in codecs[name].values():
            for knobs in kinds.values():
                unknown = {k for k in knobs
                           if not k.startswith("_")} - allowed
                assert not unknown, f"{name}: unknown knobs {unknown}"


def test_committed_table_round_trips(tmp_path):
    table = tuning.load_table()
    p = tuning.save_table(table, tmp_path / "t.json")
    assert tuning.load_table(p) == table


# --------------------------------------------------------------------------
# pipelined generic Pallas wrapper stays bit-exact
# --------------------------------------------------------------------------

# interpret=True forces num_stages=1 (off-TPU safety), so the test hook
# interpret_pipeline exercises the real multi-stage grid body; 3 stages
# over a chunk count that is NOT a multiple of 3 covers the row-padding
# remainder path.
_PIPELINE_TUNE = (("interpret_pipeline", 1), ("num_stages", 3))


@pytest.mark.parametrize("codec", registry.names())
def test_pipelined_wrapper_bit_exact(codec):
    c = registry.get(codec)
    arr = c.demo_data(4096, np.random.default_rng(11))
    ca = api.compress(arr, codec, chunk_bytes=512)
    with tuning.override(None):
        ref = api.decompress(ca, CodagEngine(EngineConfig(backend="xla")))
        piped = api.decompress(ca, CodagEngine(EngineConfig(
            backend="pallas", interpret=True, tune=_PIPELINE_TUNE)))
    np.testing.assert_array_equal(ref, arr)
    np.testing.assert_array_equal(piped, arr)


# --------------------------------------------------------------------------
# persistent compile cache across real process boundaries
# --------------------------------------------------------------------------

_CHILD = textwrap.dedent("""
    import sys, time
    cache_dir = sys.argv[1]
    from repro.core import tuning
    if cache_dir != "-":
        tuning.enable_compile_cache(cache_dir)
    import numpy as np, jax.numpy as jnp
    from repro.core import api
    from repro.kernels import ops
    arr = np.repeat(np.arange(40, dtype=np.uint32), 25)
    blob = api.compress(arr, "rle_v1", chunk_bytes=512).blobs[0]
    dev, bits = ops.table_inputs(blob)
    dev = {k: jnp.asarray(v) for k, v in dev.items()}
    lowered = ops._decode_impl.lower(
        dev, codec=blob.codec, width=blob.width,
        chunk_elems=blob.chunk_elems, backend="xla", interpret=True,
        bits=bits, epilogue=None, tune=())
    t0 = time.perf_counter()
    lowered.compile()
    print(time.perf_counter() - t0)
""")


def _compile_in_subprocess(cache_dir: str) -> float:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _CHILD, cache_dir],
                         capture_output=True, text=True, timeout=300,
                         env=env, cwd=str(ROOT))
    assert out.returncode == 0, out.stderr[-2000:]
    return float(out.stdout.strip().splitlines()[-1])


def test_compile_cache_across_processes(tmp_path):
    cache = str(tmp_path / "jit-cache")
    _compile_in_subprocess(cache)             # populate
    assert any(Path(cache).iterdir()), "cache dir stayed empty"
    warm = _compile_in_subprocess(cache)      # compile = disk load
    cold = _compile_in_subprocess("-")        # fresh process, no cache
    # the benchmark's acceptance ratio is ~10x; a unit test only asserts
    # the direction so runner noise cannot flake it
    assert warm < cold, f"cached compile not faster ({warm=} {cold=})"


def test_enable_compile_cache_warns_instead_of_swallowing(tmp_path):
    """Regression: a failing ``reset_cache()`` (cache module moved/renamed)
    used to pass silently — the user thought kernels were being persisted
    when already-jitted computations were not.  It must warn with the cause
    and still enable the cache for future compiles."""
    import jax
    from jax.experimental.compilation_cache import compilation_cache as _cc
    before = jax.config.jax_compilation_cache_dir
    orig_reset = _cc.reset_cache

    def broken_reset():
        raise RuntimeError("cache backend went away")

    try:
        _cc.reset_cache = broken_reset
        with pytest.warns(RuntimeWarning,
                          match="could not be re-initialized.*went away"):
            p = tuning.enable_compile_cache(tmp_path / "c")
        # the config-level enable still happened despite the failed reset
        assert jax.config.jax_compilation_cache_dir == str(p)
    finally:
        _cc.reset_cache = orig_reset
        jax.config.update("jax_compilation_cache_dir", before)
        with tuning._lock:
            tuning._cache_enabled_at = None
        _cc.reset_cache()   # detach the tmp dir before it is deleted


def test_enable_compile_cache_idempotent_and_midprocess(tmp_path):
    # by the time this test runs the process has jitted plenty — jax's
    # lazily-initialized cache would silently ignore a config-only enable,
    # so this doubles as the regression test for the reset_cache() fix
    import jax
    import jax.numpy as jnp
    before = jax.config.jax_compilation_cache_dir
    try:
        p1 = tuning.enable_compile_cache(tmp_path / "c")
        p2 = tuning.enable_compile_cache(tmp_path / "c")
        assert p1 == p2
        assert jax.config.jax_compilation_cache_dir == str(p1)
        jax.jit(lambda x: x * 3 + 1)(jnp.arange(9)).block_until_ready()
        assert any(p1.iterdir()), "mid-process enable wrote nothing"
    finally:
        jax.config.update("jax_compilation_cache_dir", before)
        with tuning._lock:
            tuning._cache_enabled_at = None
        from jax.experimental.compilation_cache import (
            compilation_cache as _cc)
        _cc.reset_cache()   # detach the tmp dir before it is deleted
