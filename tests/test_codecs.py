"""Codec roundtrips: every REGISTERED codec x backend x dtype.

The codec matrix is pulled from ``repro.core.registry`` so a new plugin
(e.g. ``dbp``) is covered automatically — including empty chunks,
single-element chunks, and all supported widths.

Hypothesis property tests live in test_codecs_properties.py (guarded with
``pytest.importorskip`` so the deterministic suite here never depends on
hypothesis being installed).
"""
import numpy as np
import pytest

from repro.core import api, encoders as enc, format as fmt, registry
from repro.core.engine import CodagEngine, EngineConfig

RNG = np.random.default_rng(7)

ALL_CODECS = registry.names()


def datasets():
    return {
        "long_runs_u32": np.repeat(RNG.integers(0, 50, 40),
                                   RNG.integers(1, 200, 40)).astype(np.uint32),
        "rand_u8": RNG.integers(0, 255, 777).astype(np.uint8),
        "delta_u16": (np.arange(500) * 7 + 3).astype(np.uint16),
        "mixed_u32": np.concatenate(
            [np.repeat(np.uint32(5), 100),
             RNG.integers(0, 9, 53).astype(np.uint32),
             np.arange(200, dtype=np.uint32) * 3]),
        "runs_u64": np.repeat(RNG.integers(0, 2 ** 40, 30).astype(np.uint64),
                              RNG.integers(1, 60, 30)),
        "text": np.frombuffer(b"the quick brown fox " * 40
                              + b"abcabcabc" * 25, np.uint8).copy(),
        # registry-mandated edge cases
        "empty_u32": np.zeros(0, np.uint32),
        "single_u8": np.asarray([200], np.uint8),
        "single_u16": np.asarray([40000], np.uint16),
        "single_u32": np.asarray([2 ** 31 + 7], np.uint32),
    }


ENGINES = {
    "warp_xla": EngineConfig(unit="warp", backend="xla"),
    "warp_pallas": EngineConfig(unit="warp", backend="pallas"),
    "oracle": EngineConfig(unit="warp", backend="oracle"),
    "single_thread": EngineConfig(unit="warp", all_thread=False),
    "block_unit": EngineConfig(unit="block", n_units=3),
}


# warp_xla + oracle stay in the fast tier; the interpret-mode Pallas engine
# and the provisioning ablations are several seconds per case -> nightly.
_FAST_ENGINES = ("warp_xla", "oracle")


@pytest.mark.parametrize("codec", ALL_CODECS)
@pytest.mark.parametrize("engine_name", [
    e if e in _FAST_ENGINES else pytest.param(e, marks=pytest.mark.slow)
    for e in ENGINES])
def test_roundtrip_all_backends(codec, engine_name):
    eng = CodagEngine(ENGINES[engine_name])
    for name, arr in datasets().items():
        ca = api.compress(arr, codec, chunk_bytes=600)
        got = api.decompress(ca, eng)
        assert got.dtype == arr.dtype and got.shape == arr.shape, \
            f"{codec}/{engine_name}/{name}"
        assert np.array_equal(got, arr), f"{codec}/{engine_name}/{name}"


@pytest.mark.parametrize("codec", ALL_CODECS)
@pytest.mark.parametrize("width_dtype", [np.uint8, np.uint16, np.uint32])
def test_roundtrip_all_widths(codec, width_dtype):
    """Every registered codec round-trips each supported element width."""
    info = np.iinfo(width_dtype)
    arr = np.concatenate([
        np.repeat(width_dtype(3), 70),
        RNG.integers(0, info.max, 90, endpoint=True).astype(width_dtype),
        (np.arange(80) % 250).astype(width_dtype)])
    ca = api.compress(arr, codec, chunk_bytes=333)
    got = api.decompress(ca)
    assert got.dtype == arr.dtype
    assert np.array_equal(got, arr)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_bitpack_roundtrip(backend):
    vals = RNG.integers(0, 2 ** 11, 5000).astype(np.uint32)
    ca = api.compress(vals, fmt.BITPACK, chunk_bytes=2048, bits=11)
    got = api.decompress(ca, CodagEngine(EngineConfig(backend=backend)))
    assert np.array_equal(got, vals)
    assert ca.ratio < 0.40     # 11/32 + padding


def test_ratio_on_runs():
    arr = np.repeat(np.uint32(9), 100_000)
    for codec, bound in [(fmt.RLE_V1, 0.01), (fmt.RLE_V2, 0.001)]:
        ca = api.compress(arr, codec)
        assert ca.ratio < bound, codec


def test_delta_beats_rle_v1_on_arithmetic():
    arr = np.arange(100_000, dtype=np.uint32) * 3
    r1 = api.compress(arr, fmt.RLE_V1).ratio
    r2 = api.compress(arr, fmt.RLE_V2).ratio
    # delta groups cap at 66 elems: 9B header+base+delta per 264B ~ 0.034
    assert r2 < 0.05 and r2 < r1 / 20


def test_dbp_compresses_sorted_ids():
    """dbp's target workload: sorted ids / timestamps (small FOR ranges)."""
    arr = np.cumsum(RNG.integers(0, 16, 100_000)).astype(np.uint32)
    r_dbp = api.compress(arr, fmt.DBP).ratio
    r_rle1 = api.compress(arr, fmt.RLE_V1).ratio
    # ~11 bits/elem of offsets + headers vs RLE v1 literal fallback (~1.0)
    assert r_dbp < 0.5 and r_dbp < r_rle1 / 2


def test_tdeflate_compresses_text():
    data = np.frombuffer(b"hello world, " * 5000, np.uint8).copy()
    ca = api.compress(data, fmt.TDEFLATE)
    assert api.decompress(ca).tobytes() == data.tobytes()
    assert ca.ratio < 0.1


def test_compressed_symbol_structure_table_v():
    """Table V analogue: avg compressed symbol length behaves as expected —
    run-heavy data has long symbols, random data degenerates to literals."""
    runs = np.repeat(RNG.integers(0, 9, 64).astype(np.uint8), 120)
    rand = RNG.integers(0, 255, 8000).astype(np.uint8)
    blob_runs = enc.compress(runs, fmt.RLE_V1, 1 << 14)
    blob_rand = enc.compress(rand, fmt.RLE_V1, 1 << 14)
    assert blob_runs.ratio < 0.05
    assert 0.95 < blob_rand.ratio < 1.05
