"""Multi-device distribution tests (8 fake CPU devices via subprocess —
conftest deliberately keeps the main pytest process at 1 device)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str, ndev: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """Loss on a (2,2,2) pod/data/model mesh == single-device loss."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs import get_arch, reduced
        from repro.distributed import sharding
        from repro.launch import steps as steps_lib
        from repro.models import model
        from repro.optim import adamw

        cfg = reduced(get_arch("qwen3-1.7b"))
        params = model.init_params(cfg, jax.random.key(0))
        opt_cfg = adamw.AdamWConfig(lr=1e-3)
        opt = adamw.init(params, opt_cfg)
        tokens = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab)
        labels = jax.random.randint(jax.random.key(2), (4, 32), 0, cfg.vocab)
        batch = {"tokens": tokens, "labels": labels}

        # single-device reference
        step = steps_lib.build_train_step(cfg, opt_cfg)
        _, _, loss_ref = jax.jit(step)(params, opt, batch)

        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 2, 2),
                    ("pod", "data", "model"))
        with mesh, sharding.use_mesh(mesh):
            p_sh = sharding.param_shardings(params, mesh)
            o_sh = sharding.opt_shardings(opt, params, mesh)
            from repro.configs.base import ShapeSpec
            b_sh = steps_lib.batch_shardings(
                cfg, ShapeSpec("t", 32, 4, "train"), mesh)
            pd = jax.device_put(params, p_sh)
            od = jax.device_put(opt, o_sh)
            bd = {k: jax.device_put(v, b_sh[k]) for k, v in batch.items()}
            step2 = steps_lib.build_train_step(cfg, opt_cfg)
            p2, o2, loss_sh = jax.jit(step2, in_shardings=(p_sh, o_sh, b_sh),
                                      out_shardings=None)(pd, od, bd)
        print("REF", float(loss_ref), "SHARDED", float(loss_sh))
        assert abs(float(loss_ref) - float(loss_sh)) < 1e-3
        print("PASS")
    """)
    assert "PASS" in out


@pytest.mark.slow
def test_compressed_psum_and_diloco():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.optim.grad_compress import (
            wire_bytes_compressed, wire_bytes_f32_allreduce)
        from repro.distributed import collectives, diloco

        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 2, 2),
                    ("pod", "data", "model"))
        # compressed tree-reduce over pod axis (bitpack wire + plan decode)
        f = collectives.make_tree_reduce(mesh, "pod", wire="int8")
        x = jnp.stack([jnp.full((256,), 1.0), jnp.full((256,), 3.0)])
        with mesh:
            mean, _ = jax.jit(lambda t: f(t))({"w": x})
        np.testing.assert_allclose(np.asarray(mean["w"]),
                                   np.full((256,), 2.0), rtol=0.02)

        # wire accounting: int8 beats f32 ring for big payloads
        assert wire_bytes_compressed(1 << 20, 2) < wire_bytes_f32_allreduce(1 << 20, 2)

        # DiLoCo outer sync keeps pods in agreement
        params = {"w": jnp.ones((64,)) * 0.5}
        pod_params = diloco.replicate_for_pods(params, 2, mesh)
        # pods diverge
        pod_params = {"w": pod_params["w"] + jnp.asarray([[0.1], [0.3]])}
        cfgd = diloco.DiLoCoConfig(outer_lr=1.0, outer_momentum=0.0)
        outer = diloco.init_outer_state(params, mesh=mesh, cfg=cfgd)
        sync = diloco.make_outer_sync(mesh, cfgd)
        with mesh:
            new_pod, new_outer = jax.jit(sync)(pod_params, outer)
        # anchor moved by the mean delta (0.2), pods rebased identically
        # (64-elem leaf < QBLOCK rides the uncompressed path: exact)
        np.testing.assert_allclose(np.asarray(new_outer["anchor"]["w"]),
                                   0.7 * np.ones(64), rtol=0.02)
        np.testing.assert_allclose(np.asarray(new_pod["w"][0]),
                                   np.asarray(new_pod["w"][1]))
        print("PASS")
    """)
    assert "PASS" in out


@pytest.mark.multidevice
def test_compressed_psum_matches_uncompressed():
    """collectives.compressed_psum == plain f32 psum within int8 quant
    error, and EXACTLY equals the seed reference int8 all-gather path."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.distributed import collectives as C
        from repro.optim import grad_compress as gc

        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("pod", "data"))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((2, 4096)).astype(np.float32))

        def wire(xs):
            return C.compressed_psum(xs[0], "pod")[None]
        def ref(xs):
            return gc.compressed_psum(xs[0], "pod")[None]
        kw = dict(mesh=mesh, in_specs=(P("pod"),), out_specs=P("pod"),
                  check_rep=False)
        got = np.asarray(shard_map(wire, **kw)(x))[0]
        seed = np.asarray(shard_map(ref, **kw)(x))[0]
        exact = np.asarray(x).sum(0)

        # bit-for-bit against the reference dequant-sum: the wire decode
        # (bitpack blob -> plan.dispatch -> fused epilogue) loses nothing
        np.testing.assert_array_equal(got, seed)
        # and within one int8 grid step of the true f32 sum per block
        scale = np.abs(np.asarray(x)).max() / 127.0
        assert np.abs(got - exact).max() <= 2 * scale + 1e-6
        print("PASS")
    """)
    assert "PASS" in out


@pytest.mark.multidevice
def test_outer_sync_keeps_pod_placement():
    """Regression: the post-sync pod replicas must keep their 'pod'
    NamedSharding (replicate_for_pods used to drop the mesh on rebase)."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.distributed import diloco

        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("pod", "data"))
        params = {"w": jnp.ones((512,)), "b": jnp.ones((8, 16))}
        pod_params = diloco.replicate_for_pods(params, 2, mesh)
        for k, ndim in (("w", 2), ("b", 3)):
            want = NamedSharding(mesh, P(*("pod",) + (None,) * (ndim - 1)))
            assert pod_params[k].sharding == want, (k, pod_params[k].sharding)

        cfgd = diloco.DiLoCoConfig(outer_lr=0.7, outer_momentum=0.9)
        outer = diloco.init_outer_state(params, mesh=mesh, cfg=cfgd)
        sync = diloco.make_outer_sync(mesh, cfgd)
        with mesh:
            new_pod, _ = jax.jit(sync)(pod_params, outer)
        for k, ndim in (("w", 2), ("b", 3)):
            spec = new_pod[k].sharding.spec
            assert len(spec) >= 1 and spec[0] == "pod", (k, spec)
        print("PASS")
    """)
    assert "PASS" in out


@pytest.mark.multidevice
def test_topk_psum_error_feedback_accumulates():
    """Entries below the top-k bar are carried in the residual and cross
    the wire once accumulation pushes them over it."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.distributed import collectives as C

        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("pod", "data"))
        size, frac = 1000, 0.01          # k = 10
        rng = np.random.default_rng(3)
        g = jnp.asarray(np.repeat(
            rng.standard_normal((1, size)).astype(np.float32), 2, 0))

        tune = __import__("repro.core.tuning", fromlist=["kernel_tune"]
                          ).kernel_tune("bitpack", 1)
        def body(xs, rs):
            d, nr = C.topk_psum(xs[0], rs[0], "pod", frac=frac, mean=True,
                                tune=tune)
            return d[None], nr[None]
        f = jax.jit(shard_map(body, mesh=mesh,
                              in_specs=(P("pod"), P("pod")),
                              out_specs=(P("pod"), P("pod")),
                              check_rep=False))

        res = jnp.zeros_like(g)
        dense_sum = np.zeros(size, np.float32)
        n_rounds = 30
        for _ in range(n_rounds):
            dense, res = f(g, res)
            dense_sum += np.asarray(dense)[0]
        # conservation: after many steps, total transmitted + residual
        # equals total injected (error feedback loses nothing beyond the
        # f16 grid the wire values ride)
        total = dense_sum + np.asarray(res)[0]
        np.testing.assert_allclose(total, np.asarray(g)[0] * n_rounds,
                                   rtol=1e-3, atol=2e-2)
        # and every step moved exactly k values per member
        d1, _ = f(g, jnp.zeros_like(g))
        assert (np.asarray(d1) != 0).sum() <= 2 * int(size * frac)
        print("PASS")
    """)
    assert "PASS" in out


@pytest.mark.multidevice
def test_gather_member_tables_ragged():
    """Ragged member tables: padding rows contributed by short members get
    their lens zeroed so the fused decode treats them as absent."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.core import plan as plan_mod
        from repro.core.engine import EngineConfig
        from repro.distributed import collectives as C

        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("pod", "data"))
        # member 0 has 2 real chunks, member 1 has 3; both pad to 3 rows
        counts = jnp.asarray([[2], [3]], jnp.int32)
        vals = jnp.asarray(np.arange(2 * 3 * 128, dtype=np.uint32)
                           .reshape(2, 3, 128) % 251)

        def body(v, c):
            words = C.pack_bits_rows(v[0], 8)
            dev = C.wire_dev(words, chunk_elems=128, bits=8)
            g = plan_mod.gather_member_tables(
                dev, "pod", codec="bitpack", row_counts=c[0, 0])
            return g["out_lens"][None], g["comp_lens"][None]
        f = shard_map(body, mesh=mesh, in_specs=(P("pod"), P("pod")),
                      out_specs=(P("pod"), P("pod")), check_rep=False)
        out_lens, comp_lens = f(vals, counts)
        ol = np.asarray(out_lens)[0]      # (6,) fused table
        assert ol.shape == (6,)
        np.testing.assert_array_equal(ol, [128, 128, 0, 128, 128, 128])
        cl = np.asarray(comp_lens)[0]
        assert cl[2] == 0 and (cl[[0, 1, 3, 4, 5]] > 0).all()
        print("PASS")
    """)
    assert "PASS" in out


@pytest.mark.multidevice
def test_outer_sync_pipeline_overlap_and_fault_drain(tmp_path):
    """The overlapped outer sync hides an injected link RTT behind inner
    work, and a WorkerFailure drains the in-flight sync concurrently with
    a compressed-checkpoint restore."""
    out = run_py(f"""
        import time
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.distributed import diloco, fault
        from repro.checkpoint import checkpoint as ckpt

        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("pod", "data"))
        params = {{"w": jnp.ones((4096,)) * 0.5}}
        cfgd = diloco.DiLoCoConfig(outer_lr=0.5, outer_momentum=0.0)
        outer = diloco.init_outer_state(params, mesh=mesh, cfg=cfgd)
        sync = jax.jit(diloco.make_outer_sync(mesh, cfgd))
        pod = diloco.replicate_for_pods(params, 2, mesh)
        pod = {{"w": pod["w"] + jnp.asarray([[0.1], [0.3]])}}

        pipe = diloco.OuterSyncPipeline(sync, link_rtt_s=0.2)
        pipe.launch(pod, outer)        # collective 'in flight'
        time.sleep(0.35)               # ... inner steps run meanwhile ...
        merged, outer = pipe.finish(pod)
        st = pipe.stats()
        assert st["syncs"] == 1
        assert st["overlap_frac"] >= 0.5, st
        # delayed update correct: now==snapshot so merged == synced params
        np.testing.assert_allclose(np.asarray(merged["w"][0]),
                                   np.asarray(merged["w"][1]))

        # fault path: in-flight sync drains while restore decodes a
        # compressed checkpoint
        state = {{"w": np.arange(4096, dtype=np.float32)}}
        ckpt.save("{tmp_path}", 5, state, codec="tdeflate")
        calls = {{"n": 0}}
        def step_fn(s, b):
            calls["n"] += 1
            if calls["n"] == 1:
                pipe.launch(pod, outer)
                raise fault.WorkerFailure("boom")
            return s, 0.0
        runner = fault.FaultTolerantRunner(
            step_fn, "{tmp_path}", ckpt_every=100,
            ckpt_codec="tdeflate", sync_pipeline=pipe)
        got, report = runner.run(state, iter([None] * 20), 7)
        assert report.restarts == 1
        assert not pipe.in_flight          # drained during restore
        assert pipe.stats()["syncs"] == 1  # drain doesn't count as a sync
        np.testing.assert_array_equal(np.asarray(got["w"]), state["w"])
        print("PASS")
    """)
    assert "PASS" in out


@pytest.mark.slow
def test_elastic_restore_onto_smaller_mesh(tmp_path):
    out = run_py(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.checkpoint import checkpoint as ckpt

        state = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
        mesh8 = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
        sh8 = {{"w": NamedSharding(mesh8, P("data", "model"))}}
        state8 = jax.device_put(state, sh8)
        ckpt.save("{tmp_path}", 1, state8)

        # 'restart' on a 4-device mesh (one pod lost)
        mesh4 = Mesh(np.asarray(jax.devices()[:4]).reshape(2, 2),
                     ("data", "model"))
        sh4 = {{"w": NamedSharding(mesh4, P("data", "model"))}}
        got = ckpt.restore("{tmp_path}", 1, state, shardings=sh4)
        assert got["w"].sharding == sh4["w"]
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.asarray(state["w"]))
        print("PASS")
    """)
    assert "PASS" in out


@pytest.mark.slow
def test_serve_step_sharded():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs import get_arch, reduced
        from repro.configs.base import ShapeSpec
        from repro.distributed import sharding
        from repro.launch import steps as steps_lib
        from repro.models import model

        cfg = reduced(get_arch("zamba2-2.7b"))
        params = model.init_params(cfg, jax.random.key(0))
        shape = ShapeSpec("d", 64, 8, "decode")
        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 2, 2),
                    ("pod", "data", "model"))
        with mesh, sharding.use_mesh(mesh):
            (p_sh, c_sh, b_sh), out_sh = steps_lib.serve_shardings(
                cfg, shape, mesh)
            cache = model.init_cache(cfg, 8, 64)
            cache = {k: jax.device_put(v, c_sh[k]) for k, v in cache.items()}
            pd = jax.device_put(params, p_sh)
            tok = jax.device_put(
                jnp.zeros((8, 1), jnp.int32), b_sh["tokens"])
            fn = jax.jit(steps_lib.build_serve_step(cfg),
                         in_shardings=(p_sh, c_sh, b_sh),
                         out_shardings=out_sh)
            logits, cache = fn(pd, cache, {"tokens": tok})
            assert logits.shape == (8, 1, cfg.vocab)
            assert not bool(jnp.any(jnp.isnan(logits)))
        print("PASS")
    """)
    assert "PASS" in out
