"""Multi-device distribution tests (8 fake CPU devices via subprocess —
conftest deliberately keeps the main pytest process at 1 device)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str, ndev: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """Loss on a (2,2,2) pod/data/model mesh == single-device loss."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs import get_arch, reduced
        from repro.distributed import sharding
        from repro.launch import steps as steps_lib
        from repro.models import model
        from repro.optim import adamw

        cfg = reduced(get_arch("qwen3-1.7b"))
        params = model.init_params(cfg, jax.random.key(0))
        opt_cfg = adamw.AdamWConfig(lr=1e-3)
        opt = adamw.init(params, opt_cfg)
        tokens = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab)
        labels = jax.random.randint(jax.random.key(2), (4, 32), 0, cfg.vocab)
        batch = {"tokens": tokens, "labels": labels}

        # single-device reference
        step = steps_lib.build_train_step(cfg, opt_cfg)
        _, _, loss_ref = jax.jit(step)(params, opt, batch)

        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 2, 2),
                    ("pod", "data", "model"))
        with mesh, sharding.use_mesh(mesh):
            p_sh = sharding.param_shardings(params, mesh)
            o_sh = sharding.opt_shardings(opt, params, mesh)
            from repro.configs.base import ShapeSpec
            b_sh = steps_lib.batch_shardings(
                cfg, ShapeSpec("t", 32, 4, "train"), mesh)
            pd = jax.device_put(params, p_sh)
            od = jax.device_put(opt, o_sh)
            bd = {k: jax.device_put(v, b_sh[k]) for k, v in batch.items()}
            step2 = steps_lib.build_train_step(cfg, opt_cfg)
            p2, o2, loss_sh = jax.jit(step2, in_shardings=(p_sh, o_sh, b_sh),
                                      out_shardings=None)(pd, od, bd)
        print("REF", float(loss_ref), "SHARDED", float(loss_sh))
        assert abs(float(loss_ref) - float(loss_sh)) < 1e-3
        print("PASS")
    """)
    assert "PASS" in out


@pytest.mark.slow
def test_compressed_psum_and_diloco():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.optim.grad_compress import (
            make_compressed_psum_fn, quantize_grads, topk_sparsify,
            wire_bytes_compressed, wire_bytes_f32_allreduce)
        from repro.distributed import diloco

        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 2, 2),
                    ("pod", "data", "model"))
        # compressed psum over pod axis
        f = make_compressed_psum_fn(mesh, "pod")
        x = jnp.stack([jnp.full((256,), 1.0), jnp.full((256,), 3.0)])
        with mesh:
            out = jax.jit(f)({"w": x})
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   np.full((2, 256), 4.0), rtol=0.02)

        # wire accounting: int8 beats f32 ring for big payloads
        assert wire_bytes_compressed(1 << 20, 2) < wire_bytes_f32_allreduce(1 << 20, 2)

        # DiLoCo outer sync keeps pods in agreement
        params = {"w": jnp.ones((64,)) * 0.5}
        pod_params = diloco.replicate_for_pods(params, 2, mesh)
        # pods diverge
        pod_params = {"w": pod_params["w"] + jnp.asarray([[0.1], [0.3]])}
        anchor, mom = diloco.init_outer_state(params)
        cfgd = diloco.DiLoCoConfig(outer_lr=1.0, outer_momentum=0.0)
        sync = diloco.make_outer_sync(mesh, cfgd)
        with mesh:
            new_pod, new_anchor, _ = jax.jit(sync)(pod_params, anchor, mom)
        # anchor moved by the mean delta (0.2), pods rebased identically
        np.testing.assert_allclose(np.asarray(new_anchor["w"]),
                                   0.7 * np.ones(64), rtol=0.02)
        np.testing.assert_allclose(np.asarray(new_pod["w"][0]),
                                   np.asarray(new_pod["w"][1]))
        print("PASS")
    """)
    assert "PASS" in out


@pytest.mark.slow
def test_elastic_restore_onto_smaller_mesh(tmp_path):
    out = run_py(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.checkpoint import checkpoint as ckpt

        state = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
        mesh8 = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
        sh8 = {{"w": NamedSharding(mesh8, P("data", "model"))}}
        state8 = jax.device_put(state, sh8)
        ckpt.save("{tmp_path}", 1, state8)

        # 'restart' on a 4-device mesh (one pod lost)
        mesh4 = Mesh(np.asarray(jax.devices()[:4]).reshape(2, 2),
                     ("data", "model"))
        sh4 = {{"w": NamedSharding(mesh4, P("data", "model"))}}
        got = ckpt.restore("{tmp_path}", 1, state, shardings=sh4)
        assert got["w"].sharding == sh4["w"]
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.asarray(state["w"]))
        print("PASS")
    """)
    assert "PASS" in out


@pytest.mark.slow
def test_serve_step_sharded():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs import get_arch, reduced
        from repro.configs.base import ShapeSpec
        from repro.distributed import sharding
        from repro.launch import steps as steps_lib
        from repro.models import model

        cfg = reduced(get_arch("zamba2-2.7b"))
        params = model.init_params(cfg, jax.random.key(0))
        shape = ShapeSpec("d", 64, 8, "decode")
        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 2, 2),
                    ("pod", "data", "model"))
        with mesh, sharding.use_mesh(mesh):
            (p_sh, c_sh, b_sh), out_sh = steps_lib.serve_shardings(
                cfg, shape, mesh)
            cache = model.init_cache(cfg, 8, 64)
            cache = {k: jax.device_put(v, c_sh[k]) for k, v in cache.items()}
            pd = jax.device_put(params, p_sh)
            tok = jax.device_put(
                jnp.zeros((8, 1), jnp.int32), b_sh["tokens"])
            fn = jax.jit(steps_lib.build_serve_step(cfg),
                         in_shardings=(p_sh, c_sh, b_sh),
                         out_shardings=out_sh)
            logits, cache = fn(pd, cache, {"tokens": tok})
            assert logits.shape == (8, 1, cfg.vocab)
            assert not bool(jnp.any(jnp.isnan(logits)))
        print("PASS")
    """)
    assert "PASS" in out
