"""Roofline machinery: HLO collective parser + flops model + sharding specs."""
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.configs.base import SHAPES
from repro.roofline import analysis

HLO = """
HloModule jit_step
  %x = bf16[256,1024]{1,0} parameter(0)
  %all-reduce.1 = bf16[256,1024]{1,0} all-reduce(%x), replica_groups={}
  %ag = f32[16,512]{1,0} all-gather(%y), dimensions={0}
  %rs = bf16[8,128]{1,0} reduce-scatter(%z), dimensions={0}
  %ard = (bf16[64]{0}, bf16[64]{0}) all-reduce-start(%w)
  %done = bf16[64]{0} all-reduce-done(%ard)
  %cp = u8[4096]{0} collective-permute(%q)
  %notacoll = bf16[9,9]{1,0} add(%x, %x)
"""


def test_collective_parser():
    out = analysis.collective_bytes(HLO)
    assert out["all-reduce"] == 256 * 1024 * 2 + 64 * 2  # start tuple halved
    assert out["all-gather"] == 16 * 512 * 4
    assert out["reduce-scatter"] == 8 * 128 * 2
    assert out["collective-permute"] == 4096
    assert "add" not in out


def test_roofline_terms_and_dominant():
    r = analysis.Roofline(flops=197e12, hbm_bytes=819e9 / 2,
                          coll_bytes=50e9 * 2, coll_by_op={},
                          model_flops=197e12 * 256, n_chips=256)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 0.5) < 1e-9
    assert abs(r.t_collective - 2.0) < 1e-9
    assert r.dominant == "collective"
    assert abs(r.useful_ratio - 1.0) < 1e-9


def test_model_flops_train_vs_decode():
    cfg = get_arch("olmo-1b")
    t = analysis.model_flops_for(cfg, SHAPES["train_4k"])
    d = analysis.model_flops_for(cfg, SHAPES["decode_32k"])
    n = cfg.param_count()
    assert abs(t - 6 * n * 256 * 4096) / t < 1e-9
    assert abs(d - 2 * n * 128) / d < 1e-9


def test_moe_uses_active_params():
    cfg = get_arch("qwen3-moe-235b-a22b")
    t = analysis.model_flops_for(cfg, SHAPES["train_4k"])
    assert t < 6 * cfg.param_count() * 256 * 4096 / 4   # far below total-N


def test_param_spec_rules():
    import jax
    from jax.sharding import Mesh
    from repro.distributed import sharding
    from repro.models import model

    cfg = get_arch("qwen3-1.7b")
    params = model.abstract_params(cfg)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    specs = sharding.param_specs(params, mesh)
    blocks = specs["blocks"]
    # stacked attn weights: (L, D, H*hd) -> last dim on model
    assert blocks["attn"]["wq"] == P(None, None, "model")
    assert blocks["attn"]["wo"] == P(None, "model", None)
    assert blocks["mlp"]["w_down"] == P(None, "model", None)
    assert specs["embed"] == P(None, "model")
    assert specs["lm_head"] == P(None, "model")
    # norms replicated
    assert blocks["ln1"] == P()


def test_moe_param_specs_expert_dim():
    import jax
    from jax.sharding import Mesh
    from repro.distributed import sharding
    from repro.models import model

    cfg = get_arch("qwen3-moe-235b-a22b")
    params = model.abstract_params(cfg)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    specs = sharding.param_specs(params, mesh)
    assert specs["blocks"]["moe"]["w_up"] == P(None, "model", None, None)
    assert specs["blocks"]["moe"]["router"] == P()


def test_dp_policy_replicates_weights():
    """§Perf hillclimb 1: --policy dp folds 'model' into data parallelism."""
    import jax
    from jax.sharding import Mesh
    from repro.distributed import sharding
    from repro.models import model

    cfg = get_arch("rwkv6-1.6b")
    params = model.abstract_params(cfg)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    with sharding.use_mesh(mesh, policy="dp"):
        specs = sharding.param_specs(params, mesh)
        # everything replicated
        assert all(s == P() for s in jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P)))
        # and the model axis becomes a batch axis
        assert "model" in sharding.dp_axes(mesh)
    with sharding.use_mesh(mesh, policy="tp"):
        assert "model" not in sharding.dp_axes(mesh)


def test_indivisible_dims_replicate():
    import jax
    from jax.sharding import Mesh
    from repro.distributed import sharding
    from repro.models import model

    cfg = get_arch("paligemma-3b")   # n_kv=1: wk out dim = 256, head count 1
    params = model.abstract_params(cfg)

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    specs = sharding.param_specs(params, FakeMesh())
    # kv projection (D, 1*256): 256 % 16 == 0 -> sharded; that's fine.
    # vocab 257216 % 16 == 0 -> sharded
    assert specs["lm_head"] == P(None, "model")
