"""DecompressionService: concurrency, coalescing, cache, shutdown, errors.

Covers the ISSUE-3 acceptance criterion: >= 4 concurrent same-group
requests resolve bit-exactly through FEWER engine dispatches than blobs
(window coalescing observable via ``ops.count_dispatches``), plus cache
hit/miss accounting, graceful ``close()`` draining, exception propagation
through futures, and the thread-safety regression for the dispatch
observer list.
"""
import dataclasses
import threading

import numpy as np
import pytest

from repro.core import api, format as fmt, registry
from repro.core import server as srv
from repro.core.engine import CodagEngine, EngineConfig
from repro.kernels import ops

RNG = np.random.default_rng(23)


def _runs_u32(n, seed=None):
    rng = np.random.default_rng(seed) if seed is not None else RNG
    vals = rng.integers(0, 90, max(4, n // 40)).astype(np.uint32)
    return np.repeat(vals, rng.integers(1, 80, len(vals)))[:n]


def _mixed_pool():
    """One array per registered codec (mixed group keys)."""
    items = []
    for i, name in enumerate(registry.names()):
        items.append((name,
                      registry.get(name).demo_data(600 + 40 * i, RNG)))
    return items


@pytest.fixture
def counted():
    with ops.count_dispatches() as calls:
        yield calls


def test_concurrent_mixed_codecs_bit_exact():
    """6 producer threads x every registered codec, all through one service."""
    pool = _mixed_pool()
    blobs = {name: api.compress(arr, name, chunk_bytes=512).blobs[0]
             for name, arr in pool}
    n_threads = 6
    results = [dict() for _ in range(n_threads)]
    with srv.DecompressionService(max_delay_ms=20) as svc:
        barrier = threading.Barrier(n_threads)

        def producer(tid):
            barrier.wait()
            futs = {name: svc.submit(blobs[name]) for name, _ in pool}
            results[tid] = {name: f.result() for name, f in futs.items()}

        threads = [threading.Thread(target=producer, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    for tid in range(n_threads):
        for name, arr in pool:
            got = results[tid][name]
            assert got.dtype == arr.dtype, f"{tid}/{name}"
            assert np.array_equal(got, arr), f"{tid}/{name}"


def test_window_coalescing_reduces_dispatches(counted):
    """ISSUE-3 acceptance: >= 4 concurrent same-group requests resolve
    bit-exactly through fewer engine dispatches than blobs."""
    n = 8
    arrays = [_runs_u32(700, seed=100 + i) for i in range(n)]
    blobs = [api.compress(a, fmt.RLE_V2, chunk_bytes=512).blobs[0]
             for a in arrays]
    outs = [None] * n
    # max_batch_blobs == n flushes the instant the last request lands;
    # max_delay/idle are generous so a descheduled thread still coalesces.
    with srv.DecompressionService(max_batch_blobs=n, max_delay_ms=2000,
                                  idle_ms=2000, cache_bytes=0) as svc:
        barrier = threading.Barrier(n)

        def producer(i):
            barrier.wait()
            outs[i] = svc.submit(blobs[i]).result()

        threads = [threading.Thread(target=producer, args=(i,))
                   for i in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stats = svc.stats()

    for a, o in zip(arrays, outs):
        assert np.array_equal(a, o)
    assert 1 <= len(counted) < n        # coalesced: fewer dispatches than blobs
    assert stats.blobs == n
    assert stats.dispatch_amplification < 1.0
    # all blobs share one group key, so any window issues exactly 1 dispatch
    assert len(counted) == stats.windows


def test_cache_hit_miss_accounting(counted):
    arr = _runs_u32(900, seed=7)
    other_arr = _runs_u32(900, seed=8)
    blob = api.compress(arr, fmt.RLE_V2, chunk_bytes=512).blobs[0]
    other = api.compress(other_arr, fmt.RLE_V2, chunk_bytes=512).blobs[0]
    with srv.DecompressionService(cache_bytes=8 << 20) as svc:
        first = svc.decode(blob)
        second = svc.decode(blob)          # content-identical -> cache hit
        third = svc.decode(other)          # different content -> miss
        # the cached copy is private: mutating a returned array must not
        # corrupt later hits
        second[:10] = 0
        fourth = svc.decode(blob)
        stats = svc.stats()
    assert np.array_equal(first, arr)
    assert np.array_equal(third, other_arr)
    assert np.array_equal(fourth, arr)
    assert stats.cache_hits == 2
    assert stats.cache_misses == 2
    assert len(counted) == 2               # hits issued no dispatch
    assert stats.cache_bytes > 0


def test_cache_byte_budget_evicts():
    arr = _runs_u32(800, seed=9)
    blob = api.compress(arr, fmt.RLE_V2, chunk_bytes=512).blobs[0]
    # budget smaller than one decoded blob: nothing is ever cached
    with srv.DecompressionService(cache_bytes=64) as svc:
        svc.decode(blob)
        svc.decode(blob)
        stats = svc.stats()
    assert stats.cache_hits == 0
    assert stats.cache_misses == 2
    assert stats.cache_bytes == 0


def test_in_window_dedupe_decodes_once(counted):
    """Identical payloads submitted in one window share a single decode."""
    arr = _runs_u32(600, seed=11)
    ca = api.compress(arr, fmt.RLE_V2, chunk_bytes=512)
    blobs = [ca.blobs[0]] * 5
    with srv.DecompressionService(cache_bytes=0) as svc:
        futs = svc.submit_many(blobs)
        outs = [f.result() for f in futs]
    assert len(counted) == 1
    for o in outs:
        assert np.array_equal(o, arr)
    # resolved copies are independent
    outs[0][:5] = 0
    assert np.array_equal(outs[1], arr)


def test_close_drains_without_deadlock():
    arrays = [_runs_u32(500, seed=30 + i) for i in range(12)]
    blobs = [api.compress(a, fmt.RLE_V2, chunk_bytes=512).blobs[0]
             for a in arrays]
    svc = srv.DecompressionService(max_delay_ms=5000, idle_ms=5000,
                                   max_batch_blobs=1000)
    futs = [svc.submit(b) for b in blobs]
    # close() must cut through the 5s window and drain everything queued
    svc.close(timeout=60)
    assert not svc._worker.is_alive()
    for a, f in zip(arrays, futs):
        assert f.done()
        assert np.array_equal(f.result(), a)
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit(blobs[0])
    # double close is a no-op
    svc.close()


def test_lru_reput_refreshes_recency():
    """Regression: re-putting a cached digest must count as a use.  The old
    early-return left the entry at its original position, so a digest that
    was decoded over and over could still be the first one evicted."""
    a, b, c = (np.full(100, i, np.uint8) for i in range(3))
    cache = srv._LRUCache(max_bytes=200)       # room for exactly two arrays
    cache.put("a", a)
    cache.put("b", b)
    cache.put("a", a)                          # re-put == use: refresh a
    cache.put("c", c)                          # budget forces one eviction
    assert cache.get("b") is None              # b, not a, was LRU
    assert np.array_equal(cache.get("a"), a)
    assert np.array_equal(cache.get("c"), c)
    assert cache.bytes == 200 and len(cache) == 2


def test_close_timeout_reports_unfinished_drain(monkeypatch):
    """Regression: ``close(timeout)`` never checked the worker actually
    exited — a stuck drain looked like a clean shutdown.  It must return
    False while the worker is still draining and True once it has joined
    (a second call keeps waiting rather than no-opping)."""
    arr = _runs_u32(400, seed=91)
    blob = api.compress(arr, fmt.RLE_V2, chunk_bytes=512).blobs[0]
    svc = srv.DecompressionService(max_delay_ms=1, idle_ms=1)
    release = threading.Event()
    orig = svc._process_window

    def stalled(window):
        release.wait(60)
        orig(window)

    monkeypatch.setattr(svc, "_process_window", stalled)
    fut = svc.submit(blob)
    assert svc.close(timeout=0.05) is False    # drain still running
    assert svc._worker.is_alive()
    release.set()
    assert svc.close(timeout=60) is True       # re-close waits, then joins
    assert not svc._worker.is_alive()
    assert np.array_equal(fut.result(timeout=60), arr)


def test_exception_propagates_through_future():
    good_arr = _runs_u32(600, seed=41)
    good = api.compress(good_arr, fmt.RLE_V2, chunk_bytes=512).blobs[0]
    bad = dataclasses.replace(good, codec="no_such_codec")
    with srv.DecompressionService() as svc:
        fut_bad, fut_good = svc.submit_many([bad, good])
        # the bad request fails alone; its window-mates still succeed
        with pytest.raises(ValueError, match="no_such_codec"):
            fut_bad.result(timeout=60)
        assert np.array_equal(fut_good.result(timeout=60), good_arr)
        assert svc.stats().errors == 1


def test_worker_survives_bad_blob_metadata():
    """Regression: a blob whose metadata blows up AFTER the group decode
    (inconsistent orig_shape -> reassemble raises) fails only its own
    future; window-mates resolve and the worker keeps serving."""
    good_arr = _runs_u32(600, seed=43)
    good = api.compress(good_arr, fmt.RLE_V2, chunk_bytes=512).blobs[0]
    bad = dataclasses.replace(good, orig_shape=(999_999,))
    with srv.DecompressionService() as svc:
        fut_bad, fut_good = svc.submit_many([bad, good])
        with pytest.raises(ValueError):
            fut_bad.result(timeout=60)
        assert np.array_equal(fut_good.result(timeout=60), good_arr)
        # the worker thread survived and still serves new requests
        assert np.array_equal(svc.decode(good), good_arr)
        assert svc._worker.is_alive()


def test_cancelled_future_does_not_kill_worker():
    """Regression: a caller cancelling a pending future must not crash the
    worker when it later tries to resolve it."""
    arr = _runs_u32(500, seed=44)
    blob = api.compress(arr, fmt.RLE_V2, chunk_bytes=512).blobs[0]
    with srv.DecompressionService(max_delay_ms=200, idle_ms=200) as svc:
        fut = svc.submit(blob)
        fut.cancel()
        # worker must survive resolving the cancelled future and keep going
        assert np.array_equal(svc.decode(blob), arr)
        assert svc._worker.is_alive()


def test_engine_and_service_mutually_exclusive():
    arr = _runs_u32(400, seed=45)
    ca = api.compress(arr, fmt.RLE_V2, chunk_bytes=512)
    with srv.DecompressionService() as svc:
        with pytest.raises(ValueError, match="not both"):
            api.decompress_many([ca], CodagEngine(EngineConfig()),
                                service=svc)


def test_submit_array_recombines_planes():
    arr = np.repeat(RNG.integers(0, 2 ** 50, 20).astype(np.uint64),
                    RNG.integers(1, 50, 20))
    ca = api.compress(arr, fmt.RLE_V2, chunk_bytes=512)
    assert len(ca.blobs) == 2              # lo/hi plane decomposition
    with srv.DecompressionService() as svc:
        out = svc.submit_array(ca).result(timeout=60)
    assert out.dtype == arr.dtype
    assert np.array_equal(out, arr)


def test_decode_arrays_one_dispatch_per_group(counted):
    arrays = [_runs_u32(700, seed=50 + i) for i in range(4)]
    arrays.append(RNG.integers(0, 200, 500).astype(np.uint8))
    cas = [api.compress(a, fmt.RLE_V1, chunk_bytes=512) for a in arrays]
    with srv.DecompressionService(cache_bytes=0, bucket_shapes=False) as svc:
        outs = svc.decode_arrays(cas)
    for a, o in zip(arrays, outs):
        assert np.array_equal(a, o)
    assert len(counted) == 2               # u32 group + u8 group


def test_pad_table_to_bucket_roundtrip():
    """Shape-bucketed tables (pow2 rows/cols of zero-length chunks) decode
    the real rows bit-exactly on a merged multi-blob table."""
    blobs = [api.compress(_runs_u32(700, seed=60 + i), fmt.RLE_V2,
                          chunk_bytes=512).blobs[0] for i in range(3)]
    merged = fmt.concat_blobs(blobs)
    padded = srv.pad_table_to_bucket(merged)
    assert padded.num_chunks >= merged.num_chunks
    assert padded.num_chunks & (padded.num_chunks - 1) == 0   # pow2
    eng = CodagEngine(EngineConfig())
    table = eng.decompress_table(padded)[:merged.num_chunks]
    row = 0
    for b in blobs:
        rows = table[row:row + b.num_chunks]
        row += b.num_chunks
        got = fmt.reassemble(b, rows.copy())
        assert np.array_equal(got, fmt.reassemble(
            b, eng.decompress_table(b)))


def test_stats_latency_and_window_shape():
    blobs = [api.compress(_runs_u32(500, seed=70 + i), fmt.RLE_V2,
                          chunk_bytes=512).blobs[0] for i in range(6)]
    with srv.DecompressionService(max_delay_ms=20) as svc:
        [f.result() for f in svc.submit_many(blobs)]
        stats = svc.stats()
    assert stats.windows >= 1
    assert stats.blobs == 6
    assert stats.blobs_per_window >= 1.0
    assert 0.0 <= stats.cache_hit_rate <= 1.0
    assert 0.0 <= stats.latency_p50_ms <= stats.latency_p99_ms


def test_default_service_recreated_after_close():
    svc = srv.default_service()
    assert srv.default_service() is svc
    svc.close()
    svc2 = srv.default_service()
    assert svc2 is not svc and not svc2.closed
    arr = _runs_u32(400, seed=80)
    (out,) = api.decompress_many([api.compress(arr, fmt.RLE_V2,
                                               chunk_bytes=512)])
    assert np.array_equal(out, arr)


def test_count_dispatches_thread_safe_under_churn():
    """Regression (ISSUE-3 satellite): the observer list is mutated from
    test threads while the service worker fans out dispatch records — the
    unlocked version could skip observers (del during iteration) or corrupt
    the list.  A long-lived context must see EVERY dispatch issued while
    open, regardless of concurrent register/unregister churn."""
    arr = _runs_u32(400, seed=90)
    blob = api.compress(arr, fmt.RLE_V2, chunk_bytes=512).blobs[0]
    dev, bits = ops.table_inputs(blob)
    stop = threading.Event()

    def churn():
        while not stop.is_set():
            with ops.count_dispatches():
                pass

    churners = [threading.Thread(target=churn) for _ in range(4)]
    for t in churners:
        t.start()
    try:
        n = 60
        with ops.count_dispatches() as calls:
            for _ in range(n):
                ops.decode(dev, codec=blob.codec, width=blob.width,
                           chunk_elems=blob.chunk_elems, bits=bits)
        assert len(calls) == n
    finally:
        stop.set()
        for t in churners:
            t.join()
