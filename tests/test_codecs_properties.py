"""Hypothesis property tests (system invariant: decode(encode(x)) == x).

Split from test_codecs.py so the deterministic suite collects and runs even
where hypothesis is not installed — here the whole module skips gracefully.

The codec strategies sample from ``registry.names()`` so every registered
plugin (including ``dbp`` and any future codec) is property-tested with no
edits here.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as hst  # noqa: E402

from repro.core import api, format as fmt, registry  # noqa: E402
from repro.core.engine import CodagEngine, EngineConfig  # noqa: E402

_eng = CodagEngine(EngineConfig())

ALL_CODECS = registry.names()


@settings(max_examples=25, deadline=None)
@given(hst.lists(hst.integers(0, 255), min_size=0, max_size=2000),
       hst.sampled_from(ALL_CODECS),
       hst.sampled_from([64, 333, 1024]))
def test_roundtrip_property_u8(data, codec, chunk_bytes):
    arr = np.asarray(data, np.uint8)
    ca = api.compress(arr, codec, chunk_bytes=chunk_bytes)
    assert np.array_equal(api.decompress(ca, _eng), arr)


@settings(max_examples=25, deadline=None)
@given(hst.lists(
    hst.tuples(hst.integers(0, 2 ** 32 - 1), hst.integers(1, 40)),
    min_size=1, max_size=60),
    hst.sampled_from([c for c in ALL_CODECS if c != fmt.TDEFLATE]))
def test_roundtrip_property_runs_u32(runs, codec):
    arr = np.concatenate([np.repeat(np.uint32(v), l) for v, l in runs])
    ca = api.compress(arr, codec, chunk_bytes=512)
    assert np.array_equal(api.decompress(ca, _eng), arr)


@settings(max_examples=20, deadline=None)
@given(hst.integers(0, 2 ** 31), hst.integers(-500, 500),
       hst.integers(4, 300),
       hst.sampled_from([fmt.RLE_V2, fmt.DBP]))
def test_roundtrip_property_arithmetic(base, delta, n, codec):
    arr = (base + delta * np.arange(n, dtype=np.int64)).astype(np.uint32)
    ca = api.compress(arr, codec, chunk_bytes=512)
    assert np.array_equal(api.decompress(ca, _eng), arr)


@settings(max_examples=20, deadline=None)
@given(hst.lists(hst.integers(0, 2 ** 16 - 1), min_size=1, max_size=1500),
       hst.integers(1, 17))
def test_bitpack_property(vals, bits):
    arr = (np.asarray(vals, np.uint32) & ((1 << bits) - 1))
    ca = api.compress(arr, fmt.BITPACK, chunk_bytes=777, bits=bits)
    assert np.array_equal(api.decompress(ca, _eng), arr)


@settings(max_examples=15, deadline=None)
@given(hst.binary(min_size=1, max_size=3000))
def test_tdeflate_property_bytes(data):
    arr = np.frombuffer(data, np.uint8).copy()
    ca = api.compress(arr, fmt.TDEFLATE, chunk_bytes=800)
    assert api.decompress(ca, _eng).tobytes() == data


@settings(max_examples=10, deadline=None)
@given(hst.lists(
    hst.tuples(hst.sampled_from(ALL_CODECS),
               hst.lists(hst.integers(0, 255), min_size=1, max_size=400)),
    min_size=0, max_size=6))
def test_batched_matches_per_blob_property(items):
    """Batched decode (core.batch) is bit-exact vs per-array decompress."""
    arrays = [np.asarray(data, np.uint8) for _, data in items]
    cas = api.compress_many(arrays, [c for c, _ in items], chunk_bytes=256)
    outs = api.decompress_many(cas, _eng)
    for arr, out in zip(arrays, outs):
        assert np.array_equal(out, arr)
