"""Hypothesis property tests (system invariant: decode(encode(x)) == x).

Split from test_codecs.py so the deterministic suite collects and runs even
where hypothesis is not installed — here the whole module skips gracefully.

The codec strategies sample from ``registry.names()`` so every registered
plugin (including ``dbp`` and any future codec) is property-tested with no
edits here.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as hst  # noqa: E402

from repro.core import api, format as fmt, registry  # noqa: E402
from repro.core.engine import CodagEngine, EngineConfig  # noqa: E402

_eng = CodagEngine(EngineConfig())

ALL_CODECS = registry.names()


@settings(max_examples=25, deadline=None)
@given(hst.lists(hst.integers(0, 255), min_size=0, max_size=2000),
       hst.sampled_from(ALL_CODECS),
       hst.sampled_from([64, 333, 1024]))
def test_roundtrip_property_u8(data, codec, chunk_bytes):
    arr = np.asarray(data, np.uint8)
    ca = api.compress(arr, codec, chunk_bytes=chunk_bytes)
    assert np.array_equal(api.decompress(ca, _eng), arr)


@settings(max_examples=25, deadline=None)
@given(hst.lists(
    hst.tuples(hst.integers(0, 2 ** 32 - 1), hst.integers(1, 40)),
    min_size=1, max_size=60),
    hst.sampled_from([c for c in ALL_CODECS if c != fmt.TDEFLATE]))
def test_roundtrip_property_runs_u32(runs, codec):
    arr = np.concatenate([np.repeat(np.uint32(v), l) for v, l in runs])
    ca = api.compress(arr, codec, chunk_bytes=512)
    assert np.array_equal(api.decompress(ca, _eng), arr)


@settings(max_examples=20, deadline=None)
@given(hst.integers(0, 2 ** 31), hst.integers(-500, 500),
       hst.integers(4, 300),
       hst.sampled_from([fmt.RLE_V2, fmt.DBP]))
def test_roundtrip_property_arithmetic(base, delta, n, codec):
    arr = (base + delta * np.arange(n, dtype=np.int64)).astype(np.uint32)
    ca = api.compress(arr, codec, chunk_bytes=512)
    assert np.array_equal(api.decompress(ca, _eng), arr)


@settings(max_examples=20, deadline=None)
@given(hst.lists(hst.integers(0, 2 ** 16 - 1), min_size=1, max_size=1500),
       hst.integers(1, 17))
def test_bitpack_property(vals, bits):
    arr = (np.asarray(vals, np.uint32) & ((1 << bits) - 1))
    ca = api.compress(arr, fmt.BITPACK, chunk_bytes=777, bits=bits)
    assert np.array_equal(api.decompress(ca, _eng), arr)


@settings(max_examples=15, deadline=None)
@given(hst.binary(min_size=1, max_size=3000))
def test_tdeflate_property_bytes(data):
    arr = np.frombuffer(data, np.uint8).copy()
    ca = api.compress(arr, fmt.TDEFLATE, chunk_bytes=800)
    assert api.decompress(ca, _eng).tobytes() == data


@settings(max_examples=10, deadline=None)
@given(hst.lists(
    hst.tuples(hst.sampled_from(ALL_CODECS),
               hst.lists(hst.integers(0, 255), min_size=1, max_size=400)),
    min_size=0, max_size=6))
def test_batched_matches_per_blob_property(items):
    """Batched decode (core.batch) is bit-exact vs per-array decompress."""
    arrays = [np.asarray(data, np.uint8) for _, data in items]
    cas = api.compress_many(arrays, [c for c, _ in items], chunk_bytes=256)
    outs = api.decompress_many(cas, _eng)
    for arr, out in zip(arrays, outs):
        assert np.array_equal(out, arr)


# --------------------------------------------------------------------------
# adversarial fuzz pass (ISSUE-3): worst-case shapes for every registry
# codec — degenerate run structure, saturated values, single-element and
# empty chunks, odd tails.  A bounded subset runs in the fast CI tier; the
# deep sweep (more examples, pathological chunk sizes) is nightly.
# --------------------------------------------------------------------------

_WIDTH_DTYPES = {1: np.uint8, 2: np.uint16, 4: np.uint32}


@hst.composite
def adversarial_arrays(draw):
    """Arrays built to stress decode paths, not to look like data:

    * all_runs     — one value repeated (maximal run coalescing)
    * no_runs      — neighbors always differ (zero run coverage)
    * max_vals     — every element at the dtype's max (widest literals,
                     bitpack at full bit width)
    * alternating  — period-2 flip (run length exactly 1, twice)
    * ramp         — arithmetic progression with wraparound (dbp deltas)
    * empty/single — degenerate chunk tables
    """
    width = draw(hst.sampled_from(sorted(_WIDTH_DTYPES)))
    dt = _WIDTH_DTYPES[width]
    top = int(np.iinfo(dt).max)
    pattern = draw(hst.sampled_from(
        ["all_runs", "no_runs", "max_vals", "alternating", "ramp",
         "empty", "single"]))
    if pattern == "empty":
        return np.zeros(0, dt)
    if pattern == "single":
        return np.asarray([draw(hst.integers(0, top))], dt)
    n = draw(hst.integers(1, 800))
    if pattern == "all_runs":
        return np.full(n, draw(hst.integers(0, top)), dt)
    if pattern == "max_vals":
        return np.full(n, top, dt)
    if pattern == "no_runs":
        # Weyl sequence: consecutive elements are never equal
        step = 2 * draw(hst.integers(0, top // 2)) + 1
        start = draw(hst.integers(0, top))
        return ((start + step * np.arange(n, dtype=np.uint64))
                % (top + 1)).astype(dt)
    if pattern == "alternating":
        a, b = draw(hst.integers(0, top)), draw(hst.integers(0, top))
        return np.where(np.arange(n) % 2 == 0, a, b).astype(dt)
    # ramp
    start = draw(hst.integers(0, top))
    step = draw(hst.integers(-300, 300))
    return ((start + step * np.arange(n, dtype=np.int64))
            % (top + 1)).astype(dt)


# chunk sizes chosen so vectors land on single-element chunks (width==
# chunk_bytes), odd tails (chunk_elems not dividing n), and multi-chunk
# tables; the fast subset keeps chunk counts bounded.
_FAST_CHUNK_BYTES = [97, 250, 513]
_DEEP_CHUNK_BYTES = [4, 17, 97, 250, 513, 4096]


@settings(max_examples=25, deadline=None)
@given(adversarial_arrays(), hst.sampled_from(ALL_CODECS),
       hst.sampled_from(_FAST_CHUNK_BYTES))
def test_adversarial_roundtrip(arr, codec, chunk_bytes):
    ca = api.compress(arr, codec, chunk_bytes=chunk_bytes)
    got = api.decompress(ca, _eng)
    assert got.dtype == arr.dtype and got.shape == arr.shape
    assert np.array_equal(got, arr)


@pytest.mark.slow
@settings(max_examples=120, deadline=None)
@given(adversarial_arrays(), hst.sampled_from(ALL_CODECS),
       hst.sampled_from(_DEEP_CHUNK_BYTES))
def test_adversarial_roundtrip_deep(arr, codec, chunk_bytes):
    """Nightly sweep: pathological chunk sizes (1-4 elems/chunk) included."""
    ca = api.compress(arr, codec, chunk_bytes=chunk_bytes)
    assert np.array_equal(api.decompress(ca, _eng), arr)


@settings(max_examples=15, deadline=None)
@given(hst.integers(1, 32), hst.integers(0, 2 ** 63), hst.integers(0, 900),
       hst.sampled_from(_FAST_CHUNK_BYTES))
def test_bitpack_adversarial_full_width(bits, seed, n, chunk_bytes):
    """Explicit bit widths up to the full 32, saturated values included."""
    rng = np.random.default_rng(seed)
    mask = np.uint64((1 << bits) - 1)
    arr = (rng.integers(0, 2 ** 32, n, dtype=np.uint64)
           & mask).astype(np.uint32)
    ca = api.compress(arr, fmt.BITPACK, chunk_bytes=chunk_bytes, bits=bits)
    assert np.array_equal(api.decompress(ca, _eng), arr)


_fuzz_service = None


def _cached_service():
    """One module-lived service WITH the content-hash cache on, so the
    fuzz pass exercises cache hits/dedupe (the default service keeps its
    cache off for exact dispatch accounting)."""
    global _fuzz_service
    if _fuzz_service is None or _fuzz_service.closed:
        from repro.core.server import DecompressionService
        _fuzz_service = DecompressionService(max_delay_ms=5,
                                             cache_bytes=16 << 20)
    return _fuzz_service


@settings(max_examples=10, deadline=None)
@given(hst.lists(hst.tuples(hst.sampled_from(ALL_CODECS),
                            adversarial_arrays()),
                 min_size=0, max_size=5))
def test_service_adversarial_matches_direct(items):
    """The DecompressionService paths (default engine-less routing AND an
    explicitly-cached service: micro-batch window + content-hash cache +
    in-window dedupe) stay bit-exact on adversarial inputs — including
    repeated/identical payloads, which exercise cache hits and dedupe."""
    arrays = [arr for _, arr in items]
    cas = api.compress_many(arrays, [c for c, _ in items], chunk_bytes=250)
    outs = api.decompress_many(cas)           # default-service path
    cached = api.decompress_many(cas, service=_cached_service())
    direct = api.decompress_many(cas, _eng)   # synchronous BatchPlan path
    for arr, out, hit, ref in zip(arrays, outs, cached, direct):
        assert np.array_equal(out, arr)
        assert np.array_equal(hit, arr)
        assert np.array_equal(out, ref)
