"""Golden-vector conformance suite: every registry codec, every backend.

``tests/vectors/<codec>.json`` holds small committed fixtures: raw input
bytes, deterministic encode parameters, and the content digest of the
encoded blob (``server.blob_digest``).  The suite locks two guarantees:

  * encoder conformance — re-encoding a vector reproduces the committed
    digest bit-for-bit (format drift cannot slip in silently; regenerate
    with ``scripts/make_vectors.py`` ONLY on an intentional format change);
  * decoder conformance — every backend (xla / oracle in the fast tier,
    pallas / scalar nightly) decodes every vector back to the original
    bytes exactly.

A codec present in ``registry.names()`` with no committed vectors fails
loudly here (and in ``scripts/check_registry.py``).
"""
import base64
import json
from pathlib import Path

import numpy as np
import pytest

from repro.core import encoders as enc, registry
from repro.core.server import blob_digest
from repro.kernels import ops

VEC_DIR = Path(__file__).parent / "vectors"
ALL_CODECS = registry.names()

# interpret-mode pallas and the single-thread ablation are seconds per
# case -> nightly tier, same split as test_codecs.py.
BACKENDS = [
    "xla", "oracle",
    pytest.param("pallas", marks=pytest.mark.slow),
    pytest.param("scalar", marks=pytest.mark.slow),
]


def load_vectors(codec: str):
    path = VEC_DIR / f"{codec}.json"
    if not path.exists():
        pytest.fail(
            f"codec {codec!r} is registered but has NO golden vectors at "
            f"{path} — run scripts/make_vectors.py and commit the fixtures")
    payload = json.loads(path.read_text())
    assert payload["codec"] == codec
    return payload["vectors"]


def vector_array(vec) -> np.ndarray:
    raw = base64.b64decode(vec["data_b64"])
    return np.frombuffer(raw, np.dtype(vec["dtype"])) \
             .reshape(vec["shape"]).copy()


@pytest.mark.parametrize("codec", ALL_CODECS)
def test_every_codec_has_vectors(codec):
    vectors = load_vectors(codec)
    assert len(vectors) >= 5, \
        f"{codec}: expected a full vector matrix, found {len(vectors)}"
    names = {v["name"] for v in vectors}
    # the generic edge-case set every codec must commit
    for required in ("runs_u32", "random_u8", "single_u32", "empty_u32"):
        assert required in names, f"{codec}: missing vector {required!r}"


@pytest.mark.parametrize("codec", ALL_CODECS)
def test_encoder_matches_golden_digest(codec):
    """Encoding a committed input reproduces the committed blob digest."""
    for vec in load_vectors(codec):
        arr = vector_array(vec)
        blob = enc.compress(arr, codec, vec["chunk_bytes"], bits=vec["bits"])
        assert blob.num_chunks == vec["num_chunks"], vec["name"]
        assert blob_digest(blob) == vec["blob_digest"], (
            f"{codec}/{vec['name']}: encoder output drifted from the "
            f"committed golden vector (intentional format change? "
            f"regenerate with scripts/make_vectors.py)")


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("codec", ALL_CODECS)
def test_decode_conformance_all_backends(codec, backend):
    """Every vector round-trips bit-exactly on every decode backend."""
    for vec in load_vectors(codec):
        arr = vector_array(vec)
        blob = enc.compress(arr, codec, vec["chunk_bytes"], bits=vec["bits"])
        got = ops.decode_blob(blob, backend=backend)
        assert got.dtype == arr.dtype, f"{codec}/{backend}/{vec['name']}"
        assert got.shape == arr.shape, f"{codec}/{backend}/{vec['name']}"
        assert np.array_equal(got, arr), f"{codec}/{backend}/{vec['name']}"


def test_no_orphan_vector_files():
    """Every committed vector file corresponds to a registered codec."""
    names = set(ALL_CODECS)
    for path in VEC_DIR.glob("*.json"):
        assert path.stem in names, (
            f"vector file {path.name} has no registered codec — stale "
            f"fixture or missing plugin registration")
