"""Compressed data pipeline tests."""
import numpy as np

from repro.core import format as fmt
from repro.data import pipeline


def test_synthetic_corpus_compressible():
    toks = pipeline.synthetic_corpus(1 << 16, vocab=50000)
    store = pipeline.CompressedTokenStore.build(toks, 50000,
                                                codec=fmt.RLE_V2)
    assert store.ratio < 0.9          # zipf + runs compress


def test_loader_roundtrip_and_shapes():
    toks = pipeline.synthetic_corpus(1 << 15, vocab=1000, seed=3)
    store = pipeline.CompressedTokenStore.build(
        toks, 1000, shard_tokens=1 << 13, codec=fmt.RLE_V2,
        chunk_bytes=4096)
    loader = pipeline.CompressedLoader(store, batch=4, seq=64,
                                       prefetch=False)
    it = iter(loader)
    b1 = next(it)
    b2 = next(it)
    assert b1["tokens"].shape == (4, 64)
    # labels are next-token shifted
    flat_t = np.asarray(b1["tokens"]).reshape(-1)
    flat_l = np.asarray(b1["labels"]).reshape(-1)
    np.testing.assert_array_equal(flat_t[1:], flat_l[:-1])
    # decoded stream matches the original corpus
    np.testing.assert_array_equal(flat_t, toks[:4 * 64].astype(np.int32) % 1000)


def test_loader_prefetch_thread():
    toks = pipeline.synthetic_corpus(1 << 14, vocab=500, seed=5)
    store = pipeline.CompressedTokenStore.build(
        toks, 500, shard_tokens=1 << 12, codec=fmt.RLE_V1, chunk_bytes=2048)
    loader = pipeline.CompressedLoader(store, batch=2, seq=32, prefetch=True)
    batches = []
    for i, b in enumerate(loader):
        batches.append(b)
        if i >= 3:
            break
    assert len(batches) == 4


def test_windowed_batched_decode_matches_per_shard():
    """decoded_shards(window=N) fuses shard chunks into batched dispatches
    and must be bit-exact vs. the per-shard path, in the same order."""
    from repro.core.engine import CodagEngine, EngineConfig
    from repro.kernels import ops

    toks = pipeline.synthetic_corpus(1 << 15, vocab=800, seed=7)
    store = pipeline.CompressedTokenStore.build(
        toks, 800, shard_tokens=1 << 12, codec=fmt.RLE_V2, chunk_bytes=2048)
    assert len(store.blobs) >= 4
    eng = CodagEngine(EngineConfig())
    per_shard = list(store.decoded_shards(eng, window=1))

    with ops.count_dispatches() as calls:
        windowed = list(store.decoded_shards(eng, window=4))

    assert len(windowed) == len(per_shard)
    for a, b in zip(per_shard, windowed):
        np.testing.assert_array_equal(a, b)
    # all shards share one group key -> one dispatch per window of 4 shards
    assert len(calls) == (len(store.blobs) + 3) // 4


def test_loader_service_mode_matches_engine_mode():
    """CompressedLoader(service=) replaces the ad-hoc prefetch thread with
    DecompressionService futures and must stream identical batches."""
    from repro.core.server import DecompressionService

    toks = pipeline.synthetic_corpus(1 << 14, vocab=700, seed=13)
    store = pipeline.CompressedTokenStore.build(
        toks, 700, shard_tokens=1 << 12, codec=fmt.RLE_V2, chunk_bytes=2048)
    ref_loader = pipeline.CompressedLoader(store, batch=2, seq=48,
                                           prefetch=False)
    with DecompressionService(max_delay_ms=10) as svc:
        svc_loader = pipeline.CompressedLoader(store, batch=2, seq=48,
                                               service=svc)
        for i, (ref, got) in enumerate(zip(ref_loader, svc_loader)):
            np.testing.assert_array_equal(np.asarray(ref["tokens"]),
                                          np.asarray(got["tokens"]))
            np.testing.assert_array_equal(np.asarray(ref["labels"]),
                                          np.asarray(got["labels"]))
            if i >= 3:
                break
        stats = svc.stats()
    assert stats.blobs >= len(store.blobs)
    # epoch 2 re-reads the same shards: the decoded-blob cache absorbs them
    assert stats.cache_hits > 0 or stats.blobs == len(store.blobs)


def test_decoded_shards_async_order_and_exactness():
    from repro.core.server import DecompressionService

    from repro.core.engine import CodagEngine

    toks = pipeline.synthetic_corpus(1 << 14, vocab=400, seed=17)
    store = pipeline.CompressedTokenStore.build(
        toks, 400, shard_tokens=1 << 12, codec=fmt.RLE_V1, chunk_bytes=2048)
    eng_shards = list(store.decoded_shards(CodagEngine(), window=1))
    with DecompressionService() as svc:
        svc_shards = list(store.decoded_shards_async(svc, lookahead=3))
    assert len(svc_shards) == len(eng_shards)
    for a, b in zip(eng_shards, svc_shards):
        np.testing.assert_array_equal(a, b)


def test_tdeflate_token_store():
    toks = pipeline.synthetic_corpus(1 << 14, vocab=30000, seed=9)
    store = pipeline.CompressedTokenStore.build(
        toks, 30000, codec=fmt.TDEFLATE, chunk_bytes=8192)
    loader = pipeline.CompressedLoader(store, batch=2, seq=128,
                                       prefetch=False)
    b = next(iter(loader))
    flat = np.asarray(b["tokens"]).reshape(-1)
    np.testing.assert_array_equal(flat, toks[:256].astype(np.int32) % 30000)
