"""End-to-end behaviour tests: the full training driver (compressed data
pipeline -> model -> optimizer -> checkpoints -> fault recovery) and the
serving driver, run as real subprocesses on reduced configs."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                       text=True, env=env, timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


@pytest.mark.slow
def test_train_driver_end_to_end(tmp_path):
    out = _run(["repro.launch.train", "--arch", "olmo-1b", "--preset", "tiny",
                "--steps", "30", "--batch", "4", "--seq", "128",
                "--ckpt-dir", str(tmp_path)])
    assert "OK" in out
    assert "compression ratio" in out


@pytest.mark.slow
def test_train_driver_survives_injected_failures(tmp_path):
    out = _run(["repro.launch.train", "--arch", "qwen3-1.7b", "--preset",
                "tiny", "--steps", "25", "--batch", "2", "--seq", "64",
                "--ckpt-dir", str(tmp_path), "--fail-at", "12",
                "--ckpt-every", "5"])
    assert "restarts=1" in out
    assert "OK" in out


@pytest.mark.slow
def test_train_driver_grad_int8_and_compressed_moments(tmp_path):
    out = _run(["repro.launch.train", "--arch", "olmo-1b", "--preset", "tiny",
                "--steps", "25", "--batch", "2", "--seq", "64",
                "--ckpt-dir", str(tmp_path), "--grad-int8",
                "--compress-moments"])
    assert "OK" in out


@pytest.mark.slow
def test_serve_driver_end_to_end():
    out = _run(["repro.launch.serve", "--arch", "rwkv6-1.6b", "--preset",
                "tiny", "--batch", "2", "--prompt-len", "16", "--gen", "8"])
    assert "OK" in out
