"""Tiered blob store: promotion/demotion across tiers, watermark eviction,
prefetch-overlap ordering, crash/partial-file handling, streaming restore
bit-exactness, and regressions for the lazy-restore / loader-thread /
retention fixes."""
import gc
import pickle
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.core import api, registry
from repro.core import store as bs
from repro.core.engine import CodagEngine, EngineConfig
from repro.data import pipeline


def _put_objs(backend, n, nbytes=2000):
    st = bs.TieredBlobStore(backend)
    keys = [f"k{i:03d}" for i in range(n)]
    objs = {k: np.full(nbytes // 8, i, np.int64) for i, k in enumerate(keys)}
    sizes = {k: st.put(k, objs[k]) for k in keys}
    st.close()
    return keys, objs, sizes


class GatedBackend(bs.MemoryBackend):
    """Backend whose ``get`` blocks on a per-key Event and records the
    order fetches START — the prefetch-ordering probes."""

    def __init__(self):
        super().__init__()
        self.gates = {}
        self.started = []
        self._order_lock = threading.Lock()

    def gate(self, key):
        self.gates[key] = threading.Event()
        return self.gates[key]

    def get(self, key):
        with self._order_lock:
            self.started.append(key)
        ev = self.gates.get(key)
        if ev is not None and not ev.wait(timeout=30):
            raise TimeoutError(key)
        return super().get(key)


# ---------------------------------------------------------------- tiers


def test_promotion_and_release_demotion():
    """miss -> backend fetch promotes into tier 1; release demotes (the
    payload stays only in tier 2 and pages back in on the next get)."""
    be = bs.MemoryBackend()
    keys, objs, _ = _put_objs(be, 3)
    with bs.TieredBlobStore(be) as st:
        assert not st.resident(keys[0])
        got = st.get(keys[0])                       # tier-2 -> tier-1
        np.testing.assert_array_equal(got, objs[keys[0]])
        assert st.resident(keys[0])
        assert st.get(keys[0]) is got               # tier-1 hit, same object
        s = st.stats()
        assert (s.host_hits, s.host_misses, s.backend_fetches) == (1, 1, 1)

        st.release([keys[0]])
        assert not st.resident(keys[0])
        np.testing.assert_array_equal(st.get(keys[0]), objs[keys[0]])
        s = st.stats()
        assert s.backend_fetches == 2 and s.host_released == 1


def test_tier0_decoded_cache_via_service():
    """submit_key pages the compressed blob through the store and decodes
    it; a second submit of the same key hits the service's decoded cache
    (tier 0) — both tiers' counters surface in store.stats()."""
    from repro.core.server import DecompressionService

    be = bs.MemoryBackend()
    arr = np.repeat(np.arange(64, dtype=np.int32), 50)
    ca = api.compress(arr, "rle_v2", chunk_bytes=2048)
    w = bs.TieredBlobStore(be)
    w.put("blob0", ca)
    w.close()

    st = bs.TieredBlobStore(be)
    with DecompressionService(CodagEngine(EngineConfig()),
                              cache_bytes=1 << 20, store=st) as svc:
        np.testing.assert_array_equal(
            svc.submit_key("blob0").result(timeout=60), arr)
        np.testing.assert_array_equal(
            svc.submit_key("blob0").result(timeout=60), arr)
        s = st.stats()
        assert s.decoded_hits == 1 and s.decoded_misses >= 1
        assert s.backend_fetches == 1
    st.close()


def test_watermark_eviction_hysteresis():
    """Admitting past the high mark evicts LRU entries down to the LOW
    mark in one burst — not one-out-one-in churn at the boundary."""
    be = bs.MemoryBackend()
    keys, _, sizes = _put_objs(be, 10)
    per = next(iter(sizes.values()))
    with bs.TieredBlobStore(be, host_budget_bytes=5 * per + per // 2,
                            low_watermark=0.5) as st:
        for k in keys[:5]:                     # fills to 5*per < budget
            st.get(k)
        assert st.stats().host_evictions == 0
        st.get(keys[5])                        # crosses the high mark
        s = st.stats()
        # evicted down to <= 0.5 * budget ~ 2.75*per -> 2 entries survive
        assert s.host_evictions == 4
        assert s.host_bytes <= int(0.5 * (5 * per + per // 2))
        # the just-admitted entry is never the victim
        assert st.resident(keys[5])
        # LRU order: the oldest were evicted, the newest kept
        assert not st.resident(keys[0]) and st.resident(keys[4])


def test_oversized_entry_still_admitted():
    """A blob bigger than the whole budget must still page in (the
    consumer needs it) — it is the one case resident bytes exceed the
    budget, and it never double-fetches."""
    be = bs.MemoryBackend()
    keys, objs, sizes = _put_objs(be, 2, nbytes=4000)
    with bs.TieredBlobStore(be, host_budget_bytes=100) as st:
        np.testing.assert_array_equal(st.get(keys[0]), objs[keys[0]])
        np.testing.assert_array_equal(st.get(keys[0]), objs[keys[0]])
        s = st.stats()
        assert s.backend_fetches == 1 and s.host_hits == 1


def test_prefetch_join_counts_one_fetch():
    """get() joining an in-flight prefetch counts as a hit: the page was
    already on its way in, no second backend read."""
    be = GatedBackend()
    keys, objs, _ = _put_objs(be, 1)
    ev = be.gate(keys[0])
    with bs.TieredBlobStore(be) as st:
        st.prefetch([keys[0]])
        time.sleep(0.05)                       # fetch is parked on the gate
        assert st.stats().inflight_fetches == 1
        ev.set()
        np.testing.assert_array_equal(st.get(keys[0]), objs[keys[0]])
        s = st.stats()
        assert s.backend_fetches == 1
        assert s.host_hits == 1 and s.host_misses == 1


# ------------------------------------------------- overlap loop ordering


def test_stream_windows_never_waits_on_window_i_plus_2():
    """lookahead=1 touches nothing beyond window i+1: windows 0 and 1
    must yield while window 2's backend read is BLOCKED forever."""
    be = GatedBackend()
    keys, objs, _ = _put_objs(be, 6)
    gates = [be.gate(k) for k in keys[4:6]]    # window 2 is gated shut
    with bs.TieredBlobStore(be) as st:
        it = st.stream_windows(keys, window=2, lookahead=1)
        w0 = next(it)
        w1 = next(it)                          # must NOT block
        np.testing.assert_array_equal(w0[0], objs[keys[0]])
        np.testing.assert_array_equal(w1[1], objs[keys[3]])
        # window 2's fetches may have STARTED (its prefetch was issued at
        # window 1's yield) but nothing joined them
        for g in gates:
            g.set()
        w2 = next(it)
        np.testing.assert_array_equal(w2[0], objs[keys[4]])
        with pytest.raises(StopIteration):
            next(it)


def test_stream_windows_prefetch_depth_and_order():
    """Fetches start in window order and never run more than lookahead
    windows ahead of consumption."""
    be = GatedBackend()
    keys, _, _ = _put_objs(be, 8)
    with bs.TieredBlobStore(be) as st:
        it = st.stream_windows(keys, window=2, lookahead=1)
        next(it)
        time.sleep(0.05)
        # after yielding window 0, only windows 0 and 1 may have started
        assert set(be.started) <= set(keys[:4])
        list(it)
        assert sorted(be.started) == keys      # each exactly once
        s = st.stats()
        assert s.backend_fetches == len(keys)


def test_stream_windows_exactly_once_and_bounded():
    """Budget >= (1+lookahead) windows: each key fetched exactly once,
    consumed windows released, residency bounded."""
    be = bs.MemoryBackend()
    keys, objs, sizes = _put_objs(be, 8)
    win_bytes = 2 * next(iter(sizes.values()))
    with bs.TieredBlobStore(be, host_budget_bytes=2 * win_bytes + 64) as st:
        for i, w in enumerate(st.stream_windows(keys, window=2)):
            np.testing.assert_array_equal(w[0], objs[keys[2 * i]])
            assert st.stats().host_bytes <= 2 * win_bytes + 64
        s = st.stats()
        assert s.backend_fetches == len(keys)
        assert s.host_released == len(keys)
        assert s.host_bytes == 0


def test_stream_windows_serial_when_lookahead_zero():
    """lookahead=0 issues no prefetch at all: every read starts only when
    its own window's get runs (the serial baseline the benchmark times)."""
    be = GatedBackend()
    keys, _, _ = _put_objs(be, 4)
    with bs.TieredBlobStore(be) as st:
        it = st.stream_windows(keys, window=2, lookahead=0)
        next(it)
        time.sleep(0.05)
        assert set(be.started) == set(keys[:2])


# ------------------------------------------- backend crash / bad payloads


def test_filesystem_backend_partial_file_and_corrupt_payload(tmp_path):
    be = bs.FilesystemBackend(tmp_path)
    be.put("good", pickle.dumps({"x": 1}))
    # a crash mid-put leaves only the .tmp — invisible to every read path
    (tmp_path / "crashed.tmp").write_bytes(b"partial garbage")
    assert be.list_keys() == ["good"]
    with pytest.raises(bs.BlobMissing):
        be.get("crashed")
    # a complete file with a corrupt payload surfaces as StoreError
    be.put("corrupt", b"\x80\x05 not a pickle")
    with bs.TieredBlobStore(be) as st:
        assert st.get("good") == {"x": 1}
        with pytest.raises(bs.StoreError):
            st.get("corrupt")
        with pytest.raises(bs.BlobMissing):
            st.get("never_written")


def test_filesystem_backend_put_is_atomic_and_keys_sandboxed(tmp_path):
    be = bs.FilesystemBackend(tmp_path)
    be.put("a/b/c", b"payload")
    assert be.get("a/b/c") == b"payload"
    assert be.size("a/b/c") == 7
    be.put("a/b/c", b"replaced")               # overwrite is also atomic
    assert be.get("a/b/c") == b"replaced"
    assert not list(tmp_path.rglob("*.tmp"))   # no debris after puts
    with pytest.raises(bs.StoreError):
        be.get("../../etc/passwd")


def test_prefetch_failure_surfaces_on_get():
    be = bs.MemoryBackend()
    with bs.TieredBlobStore(be) as st:
        st.prefetch(["ghost"])
        with pytest.raises(bs.BlobMissing):
            st.get("ghost")


# -------------------------------------------- streaming restore (ckpt)


@pytest.mark.parametrize("codec", registry.names())
def test_streaming_restore_bit_exact_every_codec(tmp_path, codec):
    """restore(store=) window-streams each codec's checkpoint bit-exactly
    vs the plain in-RAM restore."""
    rng = np.random.default_rng(3)
    c = registry.get(codec)
    s = {"a": jnp.asarray(c.demo_data(4096, rng)),
         "b": jnp.asarray(c.demo_data(2048, rng)),
         "small": jnp.arange(7, dtype=jnp.int32)}   # stays uncompressed
    ckpt.save(str(tmp_path), 1, s, codec=codec)
    plain = ckpt.restore(str(tmp_path), 1, s)
    with bs.filesystem_store(tmp_path, host_budget_bytes=1 << 20) as st:
        streamed = ckpt.restore(str(tmp_path), 1, s, store=st,
                                decode_window=1)
        assert st.stats().backend_fetches >= 1     # it really paged
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), plain, streamed)


def test_streaming_restore_exceeds_host_budget(tmp_path):
    """A checkpoint larger than the store's host budget restores anyway —
    windows page in, decode, and release under the watermark."""
    s = {f"l{i}": jnp.asarray(np.repeat(np.arange(80, dtype=np.int32), 40))
         for i in range(6)}
    ckpt.save(str(tmp_path), 2, s, codec="rle_v2")
    blob_bytes = sum(p.stat().st_size
                     for p in (tmp_path / "step_2").glob("*.blob"))
    with bs.filesystem_store(tmp_path,
                             host_budget_bytes=blob_bytes // 2) as st:
        got = ckpt.restore(str(tmp_path), 2, s, store=st, decode_window=2)
        stats = st.stats()
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), s, got)
    assert stats.backend_fetches == 6
    # every entry was demoted — by release (consumed windows) or by the
    # watermark racing ahead of it under the halved budget
    assert stats.host_released + stats.host_evictions == 6
    assert stats.host_bytes == 0 and stats.host_entries == 0


# ------------------------------------------------------- regressions


def test_restore_loads_blobs_lazily_per_window(tmp_path, monkeypatch):
    """Regression: restore used to read EVERY compressed blob into host
    RAM before the first decode; now loads interleave with decode windows
    even without a store."""
    s = {f"l{i}": jnp.asarray(np.repeat(np.arange(50, dtype=np.int32), 40))
         for i in range(6)}
    ckpt.save(str(tmp_path), 1, s, codec="rle_v2")

    events = []
    real_load = ckpt._load_blob
    monkeypatch.setattr(ckpt, "_load_blob",
                        lambda p: (events.append("load"), real_load(p))[1])
    real_many = api.decompress_many

    def spy_many(cas, *a, **kw):
        events.append("decode")
        return real_many(cas, *a, **kw)

    monkeypatch.setattr(api, "decompress_many", spy_many)
    got = ckpt.restore(str(tmp_path), 1, s, decode_window=2)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), s, got)
    # 3 windows of 2: load,load,decode repeated — NOT all 6 loads up front
    first_decode = events.index("decode")
    assert events.count("load") == 6 and events.count("decode") == 3
    assert sum(1 for e in events[:first_decode] if e == "load") == 2


def test_loader_iterator_dropped_without_leaking_thread():
    """Regression: dropping a prefetching CompressedLoader iterator used to
    leave its daemon worker blocked on q.put forever."""
    toks = pipeline.synthetic_corpus(1 << 14, vocab=500, seed=5)
    store = pipeline.CompressedTokenStore.build(
        toks, 500, shard_tokens=1 << 12, chunk_bytes=2048)
    loader = pipeline.CompressedLoader(store, batch=2, seq=32, prefetch=True)
    it = iter(loader)
    next(it)                                   # worker is now running
    it.close()                                 # generator finalization path
    del it
    gc.collect()
    deadline = time.time() + 5
    while time.time() < deadline:
        leaked = [t for t in threading.enumerate()
                  if t.name.startswith("codag-loader-prefetch") and
                  t.is_alive()]
        if not leaked:
            break
        time.sleep(0.05)
    assert not leaked, f"prefetch worker leaked: {leaked}"


def test_all_steps_ignores_foreign_names(tmp_path):
    s = {"w": jnp.ones((512,), jnp.float32)}
    ckpt.save(str(tmp_path), 3, s)
    (tmp_path / "step_final").mkdir()          # foreign dir
    (tmp_path / "step_7.tmp").mkdir()          # crashed save debris
    (tmp_path / "step_9").write_text("a file, not a checkpoint")
    assert ckpt.all_steps(str(tmp_path)) == [3]
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_retention_never_deletes_newer_steps(tmp_path):
    """Regression: an overlapped (slow) save of an OLDER step finishing
    last must not retire the newer checkpoint that published meanwhile."""
    s = {"w": jnp.ones((512,), jnp.float32)}
    for step in (10, 11, 12):
        ckpt.save(str(tmp_path), step, s, keep=2)
    assert sorted(ckpt.all_steps(str(tmp_path))) == [11, 12]
    # a stale writer for step 5 lands after 11/12 exist; keep=1 would have
    # wiped everything but itself under the old "keep newest" rule
    ckpt.save(str(tmp_path), 5, s, keep=1)
    steps = sorted(ckpt.all_steps(str(tmp_path)))
    assert 12 in steps and 11 in steps


# ----------------------------------------------- spill-dir token store


def test_token_store_spill_dir_bit_exact(tmp_path):
    toks = pipeline.synthetic_corpus(1 << 14, vocab=700, seed=2)
    in_mem = pipeline.CompressedTokenStore.build(
        toks, 700, shard_tokens=1 << 12, chunk_bytes=2048)
    spilled = pipeline.CompressedTokenStore.build(
        toks, 700, shard_tokens=1 << 12, chunk_bytes=2048,
        spill_dir=tmp_path, host_budget_bytes=1 << 16)
    assert spilled.spilled and not in_mem.spilled
    assert spilled.num_shards == in_mem.num_shards
    assert abs(spilled.ratio - in_mem.ratio) < 1e-9
    eng = CodagEngine(EngineConfig())
    a = np.concatenate([x.reshape(-1) for x in in_mem.decoded_shards(eng)])
    b = np.concatenate([x.reshape(-1)
                        for x in spilled.decoded_shards(eng, window=2)])
    np.testing.assert_array_equal(a, b)
    s = spilled.store.stats()
    assert s.backend_fetches == spilled.num_shards   # demand-paged once
