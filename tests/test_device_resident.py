"""Device-resident decode (ISSUE-4): on-device reassembly, fused epilogues,
zero-host-transfer batched decode, and the rewired consumers.

The acceptance spine:

  * ``reassemble_device`` / ``combine_planes_device`` are bit-exact vs the
    host path for every registered codec, including the edge geometries
    (odd tails, single-element final chunk, zero-length blobs, 64-bit plane
    recombination).
  * ``api.decompress_many(..., device_out=True)`` → ``dequant_matmul``
    completes under ``transfers.no_host_transfers()`` (which stacks
    ``jax.transfer_guard("disallow")`` on the repo's d2h funnel) — the CI
    ``no-host-transfer`` gate runs ``test_no_host_transfer_gate``.
"""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api, batch, registry, transfers
from repro.core import format as fmt
from repro.core.engine import CodagEngine, EngineConfig
from repro.core.server import DecompressionService
from repro.kernels import dequant_matmul as dqm
from repro.kernels import ops
from repro.kernels.harness import Epilogue

ENGINE = CodagEngine(EngineConfig())

# odd tail / single-element final chunk / zero-length / multi-chunk exact
EDGE_SIZES = (0, 1, 1025, 4096, 4097)


def _demo(codec_name: str, n: int, seed: int = 0) -> np.ndarray:
    codec = registry.get(codec_name)
    if n == 0:
        return np.zeros(0, np.uint8 if codec.byte_stream else np.uint32)
    return codec.demo_data(n, np.random.default_rng(seed))[:n]


# --------------------------------------------------------------------------
# reassembly: device path bit-exact vs host path (ISSUE-4 satellite)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("codec", registry.names())
@pytest.mark.parametrize("n", EDGE_SIZES)
def test_reassemble_device_matches_host(codec, n):
    ca = api.compress(_demo(codec, n), codec, chunk_bytes=1024)
    host = api.decompress(ca, ENGINE)
    [dev] = api.decompress_many([ca], ENGINE, device_out=True)
    assert isinstance(dev, jnp.ndarray)
    out = np.asarray(dev)
    assert out.dtype == host.dtype and out.shape == host.shape
    assert np.array_equal(out, host)


@pytest.mark.parametrize("codec", registry.names())
def test_reassemble_device_blobwise(codec):
    """Single-blob helper path (engine.decompress_device) incl. odd tail."""
    ca = api.compress(_demo(codec, 777), codec, chunk_bytes=512)
    for blob in ca.blobs:
        host = fmt.reassemble(blob, ENGINE.decompress_table(blob))
        dev = ENGINE.decompress_device(blob)
        assert np.array_equal(np.asarray(dev), host)


@pytest.mark.parametrize("codec", ["rle_v2", "tdeflate"])
@pytest.mark.parametrize("dtype", ["int64", "uint64", "float64"])
def test_64bit_plane_recombine_device(codec, dtype):
    """8-byte dtypes: plane split (rle_v2) and u32-pair view (tdeflate byte
    stream) both recombine on device bit-exactly, under 64-bit jax types."""
    from jax.experimental import enable_x64
    rng = np.random.default_rng(3)
    if dtype == "float64":
        arr = np.round(rng.normal(size=1003), 2).astype(np.float64)
    else:
        arr = rng.integers(0, 5000, 1003).astype(dtype)
    ca = api.compress(arr, codec, chunk_bytes=1024)
    host = api.decompress(ca, ENGINE)
    assert host.dtype == np.dtype(dtype)
    with enable_x64():
        [dev] = api.decompress_many([ca], ENGINE, device_out=True)
        assert str(dev.dtype) == dtype
        assert np.array_equal(np.asarray(dev), host)


def test_64bit_device_without_x64_raises():
    arr = np.arange(100, dtype=np.int64)
    ca = api.compress(arr, "rle_v2", chunk_bytes=512)
    with pytest.raises(ValueError, match="64-bit"):
        api.decompress_many([ca], ENGINE, device_out=True)


def test_ragged_scatter_indices():
    """The precomputed per-row-destination gather handles layouts the
    contiguous reshape+trim cannot: ragged rows, interior zero-length
    chunks.  (Standard blobs return indices=None — the fast path.)"""
    out_lens = np.array([8, 3, 0, 5], np.int32)
    chunk_elems, total = 8, int(out_lens.sum())
    blob = fmt.CompressedBlob(
        codec="rle_v1", width=4, chunk_elems=chunk_elems, total_elems=total,
        orig_dtype="uint32", orig_shape=(total,),
        comp=np.zeros((4, 1), np.uint8), comp_lens=np.ones(4, np.int32),
        out_lens=out_lens)
    idx = fmt.reassemble_indices(blob)
    assert idx is not None and idx.shape == (total,)
    table = np.arange(4 * chunk_elems, dtype=np.uint32).reshape(4, -1)
    want = np.concatenate([row[:l] for row, l in zip(table, out_lens)])
    got = fmt.reassemble_device(blob, jnp.asarray(table))
    assert np.array_equal(np.asarray(got), want)
    # the standard layout takes the index-free path
    ca = api.compress(np.arange(1025, dtype=np.uint32), "rle_v2",
                      chunk_bytes=1024)
    assert fmt.reassemble_indices(ca.blobs[0]) is None


def test_batchplan_carries_scatter():
    blobs = [api.compress(_demo("rle_v2", n), "rle_v2",
                          chunk_bytes=1024).blobs[0] for n in (1025, 4097)]
    plan = batch.BatchPlan.build(blobs)
    assert all(len(g.scatter) == len(g.blob_ids) for g in plan.groups)
    plan.stage()
    outs = plan.execute_device(ENGINE)
    for blob, out in zip(blobs, outs):
        assert np.array_equal(np.asarray(out),
                              fmt.reassemble(blob, ENGINE.decompress_table(blob)))


# --------------------------------------------------------------------------
# fused epilogues
# --------------------------------------------------------------------------


def test_epilogue_cast_and_view():
    arr = _demo("rle_v2", 2050)
    ca = api.compress(arr, "rle_v2", chunk_bytes=1024)
    [f32] = api.decompress_many([ca], ENGINE, device_out=True,
                                epilogue=Epilogue(out_dtype="float32"))
    assert f32.dtype == jnp.float32 and f32.shape == arr.shape
    assert np.array_equal(np.asarray(f32), arr.astype(np.float32))
    [i32] = api.decompress_many([ca], ENGINE, device_out=True,
                                epilogue=Epilogue(view_dtype="int32"))
    assert i32.dtype == jnp.int32
    assert np.array_equal(np.asarray(i32), arr.view(np.int32))


def test_epilogue_dequant_scale_zero():
    arr = _demo("bitpack", 1500)
    ca = api.compress(arr, "bitpack", chunk_bytes=1024)
    epi = Epilogue(scale_key="epi_s", zero_key="epi_z")
    operands = {"epi_s": np.float32(0.25), "epi_z": np.uint32(3)}
    [out] = api.decompress_many([ca], ENGINE, device_out=True, epilogue=epi,
                                epilogue_operands=operands)
    assert out.dtype == jnp.float32
    want = (arr.astype(np.float32) - 3.0) * 0.25
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-6)


def test_epilogue_block_unit_engine():
    """Scalar epilogue operands replicate via closure on the block-unit
    (RAPIDS-ablation) engine instead of breaking the lax.scan leading-dim
    contract."""
    arr = _demo("rle_v2", 3000)
    ca = api.compress(arr, "rle_v2", chunk_bytes=512)
    block = CodagEngine(EngineConfig(unit="block", n_units=4))
    epi = Epilogue(scale_key="epi_s", zero_key="epi_z")
    operands = {"epi_s": np.float32(0.5), "epi_z": np.uint32(1)}
    [out] = api.decompress_many([ca], block, device_out=True, epilogue=epi,
                                epilogue_operands=operands)
    want = (arr.astype(np.float32) - 1.0) * 0.5
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-6)


def test_epilogue_on_plane_decomposed_raises():
    """An epilogue over a plane-split 64-bit array must refuse rather than
    silently return the transformed lo plane."""
    from jax.experimental import enable_x64
    arr = np.arange(500, dtype=np.int64)
    ca = api.compress(arr, "rle_v2", chunk_bytes=512)
    assert len(ca.blobs) == 2
    with enable_x64():
        with pytest.raises(ValueError, match="plane"):
            api.decompress_many([ca], ENGINE, device_out=True,
                                epilogue=Epilogue(out_dtype="float32"))


def test_epilogue_requires_device_out():
    ca = api.compress(_demo("rle_v2", 100), "rle_v2", chunk_bytes=512)
    with pytest.raises(ValueError, match="device_out"):
        api.decompress_many([ca], ENGINE, epilogue=Epilogue(out_dtype="f4"))


def test_epilogue_custom_fn():
    arr = _demo("rle_v2", 512)
    ca = api.compress(arr, "rle_v2", chunk_bytes=1024)
    epi = Epilogue(out_dtype="int32", fn=lambda out, dev: out + 7)
    [out] = api.decompress_many([ca], ENGINE, device_out=True, epilogue=epi)
    assert np.array_equal(np.asarray(out), arr.astype(np.int32) + 7)


# --------------------------------------------------------------------------
# transfer accounting + the CI gate
# --------------------------------------------------------------------------


def test_to_host_funnel_counts_and_guards():
    x = jnp.arange(16)
    with transfers.count_host_transfers() as c:
        transfers.to_host(x)
    assert c["d2h"] == 1 and c["bytes"] == x.nbytes
    with transfers.no_host_transfers():
        with pytest.raises(RuntimeError, match="no_host_transfers"):
            transfers.to_host(x)
    transfers.to_host(x)    # guard lifted


def test_count_host_transfers_overlapping_contexts():
    """Closing one context must not unregister another holding an
    equal-valued (all-zero) counter dict — removal is by identity."""
    x = jnp.arange(8)
    with transfers.count_host_transfers() as a:
        with transfers.count_host_transfers() as b:
            pass                      # b closes while a == b == zeros
        transfers.to_host(x)
    assert a["d2h"] == 1              # a kept counting
    assert b["d2h"] == 0              # b stopped at close


def test_device_out_decode_zero_host_transfers():
    """Every registered codec decodes device-out with zero d2h crossings."""
    cas = [api.compress(_demo(n, 3000), n, chunk_bytes=2048)
           for n in registry.names()]
    with transfers.count_host_transfers() as c:
        outs = api.decompress_many(cas, ENGINE, device_out=True)
        for o in outs:
            o.block_until_ready()
    assert c["d2h"] == 0
    # while the host path funnels exactly one d2h per fused group
    with transfers.count_host_transfers() as c:
        api.decompress_many(cas, ENGINE)
    assert c["d2h"] == batch.BatchPlan.build(
        [b for ca in cas for b in ca.blobs]).num_dispatches


def test_no_host_transfer_gate():
    """The CI gate (ISSUE-4 acceptance): compressed weights → device decode
    with fused zero-point epilogue → dequant matmul, with the transfer
    guard armed for the steady-state pass.  Any reintroduced host
    materialization (``np.asarray`` on the decode path, an unstaged
    operand) fails loudly."""
    rng = np.random.default_rng(7)
    q = rng.integers(-8, 8, (256, 128)).astype(np.int8)
    s = rng.uniform(0.01, 0.1, (1, 128)).astype(np.float32)
    x = rng.normal(size=(128, 256)).astype(np.float32)
    ca = dqm.compress_weights(q, "bitpack", zero_point=8)
    epi, operands = dqm.weight_epilogue(8)
    operands = {k: jnp.asarray(v) for k, v in operands.items()}  # pre-stage
    x_dev, s_dev = jnp.asarray(x), jnp.asarray(s)

    def consume():
        [qd] = api.decompress_many([ca], ENGINE, device_out=True,
                                   epilogue=epi, epilogue_operands=operands)
        assert qd.dtype == jnp.int8
        return dqm.dequant_matmul(x_dev, qd, s_dev, interpret=True)

    warm = consume()                      # compiles + stages
    warm.block_until_ready()
    with transfers.count_host_transfers() as cnt:
        with transfers.no_host_transfers():
            y = consume()
            y.block_until_ready()
    assert cnt["d2h"] == 0
    want = dqm.ref_dequant_matmul(x_dev, jnp.asarray(q), s_dev)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_staged_plan_reuse_under_guard():
    """A pre-staged BatchPlan replays decode→scatter→epilogue with zero
    transfers in either direction (the steady-state serving pattern)."""
    rng = np.random.default_rng(11)
    q = rng.integers(-8, 8, (128, 128)).astype(np.int8)
    ca = dqm.compress_weights(q, zero_point=8)
    epi, operands = dqm.weight_epilogue(8)
    plan = batch.BatchPlan.build(ca.blobs).stage()
    plan.execute_device(ENGINE, epilogue=epi,
                        epilogue_operands=operands)[0].block_until_ready()
    with transfers.no_host_transfers():
        [qd] = plan.execute_device(ENGINE, epilogue=epi,
                                   epilogue_operands=operands)
        qd.block_until_ready()
    assert np.array_equal(np.asarray(qd), q)


# --------------------------------------------------------------------------
# rewired consumers
# --------------------------------------------------------------------------


def test_dequant_matmul_consumer_end_to_end():
    rng = np.random.default_rng(5)
    q = rng.integers(-8, 8, (256, 128)).astype(np.int8)
    s = rng.uniform(0.01, 0.1, (1, 128)).astype(np.float32)
    x = rng.normal(size=(128, 256)).astype(np.float32)
    ca = dqm.compress_weights(q, zero_point=8)
    xd, sd = jnp.asarray(x), jnp.asarray(s)
    y = dqm.decompress_dequant_matmul(xd, ca, sd, zero_point=8,
                                      engine=ENGINE, interpret=True)
    want = dqm.ref_dequant_matmul(xd, jnp.asarray(q), sd)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
    # steady state: the staged plan is cached on ca — repeat calls run the
    # whole decode→consume path with zero transfers in either direction
    with transfers.no_host_transfers():
        y2 = dqm.decompress_dequant_matmul(xd, ca, sd, zero_point=8,
                                           engine=ENGINE, interpret=True)
        y2.block_until_ready()
    np.testing.assert_allclose(np.asarray(y2), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_service_device_out():
    """The service serves device-resident results; its cache keeps host
    bytes and hands device requesters views of them on a hit."""
    arr = _demo("rle_v2", 5000)
    blob = api.compress(arr, "rle_v2", chunk_bytes=2048).blobs[0]
    with DecompressionService(CodagEngine(EngineConfig()),
                              cache_bytes=1 << 20) as svc:
        fd = svc.submit(blob, device_out=True)
        fh = svc.submit(blob)
        dev, host = fd.result(), fh.result()
        assert isinstance(dev, jnp.ndarray) and isinstance(host, np.ndarray)
        assert np.array_equal(np.asarray(dev), arr)
        assert np.array_equal(host, arr)
        # second round: cache hit resolves a device view, no new dispatch
        with ops.count_dispatches() as calls:
            hit = svc.submit(blob, device_out=True).result()
        assert isinstance(hit, jnp.ndarray)
        assert np.array_equal(np.asarray(hit), arr)
        assert len(calls) == 0
        assert svc.stats().cache_hits >= 1


def test_service_device_window_no_d2h():
    """An all-device window on a cache-less service never materializes the
    group table on the host (zero funnel crossings on the worker)."""
    cas = [api.compress(_demo("rle_v2", n, seed=n), "rle_v2",
                        chunk_bytes=1024) for n in (900, 1800)]
    with DecompressionService(CodagEngine(EngineConfig()),
                              cache_bytes=0) as svc:
        with transfers.count_host_transfers() as c:
            outs = svc.decode_arrays(cas, device_out=True)
            for o in outs:
                o.block_until_ready()
        assert c["d2h"] == 0
        for ca, out in zip(cas, outs):
            assert np.array_equal(np.asarray(out), api.decompress(ca, ENGINE))


def test_checkpoint_restore_device(tmp_path):
    from repro.checkpoint import checkpoint as ckpt
    rng = np.random.default_rng(9)
    state = {"w": rng.normal(size=(64, 64)).astype(np.float32),
             "m": rng.integers(0, 200, (128, 32)).astype(np.int32),
             "small": np.float32(1.5)}
    ckpt.save(str(tmp_path), 3, state, codec="rle_v2")
    out = ckpt.restore(str(tmp_path), 3, state, device_out=True)
    for k, v in state.items():
        assert isinstance(out[k], jnp.ndarray), (k, type(out[k]))
        assert str(out[k].dtype) == str(np.asarray(v).dtype)
        assert np.array_equal(np.asarray(out[k]), v)


def test_pipeline_device_shards():
    from repro.data import pipeline as pl
    toks = pl.synthetic_corpus(40000, 500, seed=2)
    store = pl.CompressedTokenStore.build(toks, 500, shard_tokens=8192,
                                          chunk_bytes=2048)
    host = list(store.decoded_shards(ENGINE, window=2))
    dev = list(store.decoded_shards(ENGINE, window=2, device_out=True))
    assert len(host) == len(dev)
    for h, d in zip(host, dev):
        assert isinstance(d, jnp.ndarray) and d.dtype == jnp.int32
        assert np.array_equal(np.asarray(d), h)
    loader = pl.CompressedLoader(store, batch=2, seq=128, engine=ENGINE,
                                 prefetch=False, device_out=True)
    b = next(iter(loader))
    assert isinstance(b["tokens"], jnp.ndarray)
    assert b["tokens"].shape == (2, 128)
    # identical token stream to the host loader
    hb = next(iter(pl.CompressedLoader(store, batch=2, seq=128,
                                       engine=ENGINE, prefetch=False)))
    assert np.array_equal(np.asarray(b["tokens"]), np.asarray(hb["tokens"]))


# --------------------------------------------------------------------------
# observer TOCTOU regression (ISSUE-4 satellite)
# --------------------------------------------------------------------------


def test_observer_register_dispatch_race():
    """Regression: ``ops.decode``'s observer fan-out ran its truthiness
    check outside ``_observers_lock`` (check-then-act).  With the fan-out
    fully under the lock, a context open for the whole run records EVERY
    dispatch exactly once, and a context records nothing after it closes —
    under a racing register/unregister thread pair."""
    arr = _demo("rle_v2", 600)
    blob = api.compress(arr, "rle_v2", chunk_bytes=512).blobs[0]
    dev, bits = ops.table_inputs(blob)
    n_dispatch, errors = 120, []
    stop = threading.Event()

    def dispatcher():
        try:
            for _ in range(n_dispatch):
                ops.decode(dev, codec=blob.codec, width=blob.width,
                           chunk_elems=blob.chunk_elems, bits=bits)
        except BaseException as e:  # pragma: no cover
            errors.append(e)
        finally:
            stop.set()

    closed_lens = []

    def churner():
        while not stop.is_set():
            with ops.count_dispatches() as calls:
                pass
            closed_lens.append((calls, len(calls)))

    with ops.count_dispatches() as outer:
        threads = [threading.Thread(target=dispatcher)] + \
                  [threading.Thread(target=churner) for _ in range(3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert not errors
    assert len(outer) == n_dispatch          # no lost or duplicated records
    # nothing was appended to any context after it closed
    for calls, len_at_close in closed_lens:
        assert len(calls) == len_at_close
