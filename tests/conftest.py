# NOTE: no XLA_FLAGS here — smoke tests must see 1 device (the dry-run
# sets its own 512-device flag in its own process; multi-device tests
# spawn subprocesses).
import os

import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")


# Every jitted program stays resident in jax's in-process executable cache,
# and each one is several small ORC-JIT code mappings.  A full single-process
# run of this suite compiles enough decode kernels to exhaust the kernel's
# vm.max_map_count (65530 by default) — mmap then fails inside LLVM mid-
# compile and the process segfaults.  Dropping the caches once the map count
# nears the ceiling costs a few recompiles and keeps the run alive.
_MAP_GUARD = 40_000


def _map_count() -> int:
    try:
        with open("/proc/self/maps") as f:
            return sum(1 for _ in f)
    except OSError:        # non-Linux: no map table, no map limit
        return 0


@pytest.fixture(autouse=True)
def _bound_resident_executables():
    if os.path.exists("/proc/self/maps") and _map_count() > _MAP_GUARD:
        import gc

        import jax
        jax.clear_caches()
        gc.collect()
    yield
