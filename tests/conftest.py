# NOTE: no XLA_FLAGS here — smoke tests must see 1 device (the dry-run
# sets its own 512-device flag in its own process; multi-device tests
# spawn subprocesses).
import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
