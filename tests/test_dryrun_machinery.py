"""Dry-run machinery on a small mesh (subprocess, 8 devices): lower+compile
a reduced arch through the exact run_cell pipeline, probe-corrected costs."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_probe_corrected_costs_small_mesh():
    body = textwrap.dedent("""
        import os
        # NOTE: repro.launch.dryrun sets XLA_FLAGS=512 at import (its
        # first-two-lines contract); import it FIRST, then override to 8
        # before jax initializes its backend.
        from repro.launch import dryrun
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, jax, numpy as np
        from jax.sharding import Mesh
        from repro.configs import get_arch, reduced
        from repro.configs.base import ShapeSpec
        from repro.distributed import sharding

        cfg = dataclasses.replace(reduced(get_arch("qwen3-1.7b"), n_layers=4),
                                  dtype="bfloat16")
        shape = ShapeSpec("t", 64, 8, "train")
        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 2, 2),
                    ("pod", "data", "model"))
        with mesh, sharding.use_mesh(mesh):
            compiled = dryrun._compile_cell(cfg, shape, mesh, unroll=False)
            raw = dryrun._raw_costs(compiled)
            cost = dryrun.probe_costs(cfg, shape, mesh)
        # probe-corrected flops must exceed the scan-undercounted raw flops
        assert cost["flops"] > raw["flops"] * 1.5, (cost["flops"], raw["flops"])
        # and be within 3x of the analytic 6ND estimate (remat/attention slack)
        n = cfg.param_count()
        model_flops = 6 * n * 64 * 8 / 8  # per device
        assert 0.3 < cost["flops"] / model_flops < 4.0, \
            (cost["flops"], model_flops)
        print("PASS", raw["flops"], cost["flops"])
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", body], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stderr[-4000:]
    assert "PASS" in r.stdout
