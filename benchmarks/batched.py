"""Batched vs per-blob decompression: launches-per-restore + throughput.

The scenario is a checkpoint / data-pipeline load of N small arrays (mixed
codecs and dtypes).  The per-blob loop issues one engine dispatch per blob —
the few-streams provisioning pathology CODAG critiques — while the batch
scheduler coalesces every chunk of every blob into one dispatch per
(codec, width, chunk_elems, bits) group.

    PYTHONPATH=src python -m benchmarks.batched [--smoke] [--out FILE.json]

Emits ``name,value,derived`` CSV rows (benchmarks/run.py convention) and,
with --out, a JSON artifact (the CI perf-trajectory file BENCH_batched.json).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import codec_matrix, demo_elems, write_bench_json
from repro.core import api, registry
from repro.core.engine import CodagEngine, EngineConfig
from repro.kernels import ops


def build_restore_set(n_arrays: int, kb_per_array: int, seed: int = 0):
    """Mixed-codec arrays shaped like a model-state restore: every
    registered codec contributes its own ``demo_data`` workload."""
    rng = np.random.default_rng(seed)
    codecs = codec_matrix()
    arrays, chosen = [], []
    for i in range(n_arrays):
        name = codecs[i % len(codecs)]
        codec = registry.get(name)
        arrays.append(codec.demo_data(demo_elems(codec, kb_per_array * 1024),
                                      rng))
        chosen.append(name)
    return arrays, chosen


def _time(fn, iters: int):
    fn()  # warmup (jit trace)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def run(n_arrays: int = 16, kb_per_array: int = 64, iters: int = 3,
        chunk_bytes: int = 16 * 1024, seed: int = 0):
    arrays, codecs = build_restore_set(n_arrays, kb_per_array, seed)
    cas = api.compress_many(arrays, codecs, chunk_bytes=chunk_bytes)
    engine = CodagEngine(EngineConfig())
    total_bytes = sum(a.nbytes for a in arrays)

    with ops.count_dispatches() as c:
        per_blob = [api.decompress(ca, engine) for ca in cas]
    launches_loop = len(c)
    with ops.count_dispatches() as c:
        batched = api.decompress_many(cas, engine)
    launches_batched = len(c)

    for a, p, b in zip(arrays, per_blob, batched):
        assert np.array_equal(a, p) and np.array_equal(a, b)

    t_loop = _time(lambda: [api.decompress(ca, engine) for ca in cas], iters)
    t_batch = _time(lambda: api.decompress_many(cas, engine), iters)

    rows = [
        ("batched/n_arrays", n_arrays, ""),
        ("batched/total_MB", total_bytes / 1e6, ""),
        ("batched/launches_per_restore/loop", launches_loop, ""),
        ("batched/launches_per_restore/batched", launches_batched,
         launches_loop / max(1, launches_batched)),
        ("batched/throughput_MBps/loop", total_bytes / t_loop / 1e6, ""),
        ("batched/throughput_MBps/batched", total_bytes / t_batch / 1e6,
         t_loop / t_batch),
        ("batched/speedup", t_loop / t_batch, ""),
    ]
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI: finishes in well under a minute")
    ap.add_argument("--n-arrays", type=int, default=16)
    ap.add_argument("--kb-per-array", type=int, default=64)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--out", default=None, help="also write a JSON artifact")
    args = ap.parse_args()
    if args.smoke:
        args.n_arrays, args.kb_per_array, args.iters = 8, 8, 1

    rows = run(args.n_arrays, args.kb_per_array, args.iters)
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value},{derived}")

    if args.out:
        cfg = {"n_arrays": args.n_arrays, "kb_per_array": args.kb_per_array,
               "iters": args.iters, "smoke": bool(args.smoke)}
        print(f"# wrote {write_bench_json(args.out, 'batched', cfg, rows)}")


if __name__ == "__main__":
    main()
