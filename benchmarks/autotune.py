"""Autotuner + persistent-compile-cache benchmark (BENCH_autotune.json).

Two claims, one artifact:

  * tuned vs hand-picked — ``core.tuning.autotune`` searches each codec's
    knob space (chunk geometry; kernel knobs on real Pallas backends)
    against its registry ``demo_data`` and reports tuned and default
    decoded MB/s side by side (``autotune/<codec>/speedup``).
  * cold start with vs without the persistent compile cache — three child
    processes around one temp cache dir: populate it, re-compile WITH it
    (a disk load), re-compile WITHOUT it (full XLA compilation).  Each
    probe times ``ops._decode_impl.lower(...).compile()`` per codec —
    backend compilation only, since tracing/lowering is never cached —
    and ``autotune/compile_cache_speedup`` is the no-cache/with-cache
    ratio (the acceptance bar is >= 10x).

    PYTHONPATH=src python -m benchmarks.autotune [--smoke] [--out F.json]
        [--write-table PATH]    # merge winners into a tuned-defaults table

``--write-table src/repro/core/tuned_defaults.json`` is how the committed
table is regenerated on a new device kind (entries for other kinds are
preserved; see ``tuning.merge_tables``).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time
from pathlib import Path

PROBE_CHUNK_BYTES = 4096


def _probe(cache_dir: str, size_kb: int) -> dict:
    """Child-process body: compile one decode per codec, timing only the
    backend-compile step.  ``cache_dir`` empty = no persistent cache."""
    if cache_dir:
        from repro.core import tuning
        tuning.enable_compile_cache(cache_dir)
    import jax.numpy as jnp
    import numpy as np

    from repro.core import api, registry
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    per = {}
    total = 0.0
    for name in registry.names():
        codec = registry.get(name)
        if codec.demo_data is None:
            continue
        n = max(1024, size_kb * 1024 // (1 if codec.byte_stream else 4))
        arr = codec.demo_data(n, rng)
        blob = api.compress(arr, name, chunk_bytes=PROBE_CHUNK_BYTES).blobs[0]
        dev, bits = ops.table_inputs(blob)
        dev = {k: jnp.asarray(v) for k, v in dev.items()}
        lowered = ops._decode_impl.lower(
            dev, codec=blob.codec, width=blob.width,
            chunk_elems=blob.chunk_elems, backend="xla", interpret=True,
            bits=bits, epilogue=None, tune=())
        t0 = time.perf_counter()
        lowered.compile()
        dt = time.perf_counter() - t0
        per[name] = round(dt * 1e3, 3)
        total += dt
    return {"total_ms": round(total * 1e3, 3), "per_codec_ms": per}


def _run_probe(cache_dir: str, size_kb: int) -> dict:
    """Run :func:`_probe` in a FRESH interpreter (the persistent cache only
    matters across processes: in-process jit caches would mask it)."""
    env = dict(os.environ)
    src = str(Path(__file__).resolve().parent.parent / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.autotune", "--probe", cache_dir,
         "--probe-kb", str(size_kb)],
        capture_output=True, text=True, env=env, timeout=600,
        cwd=str(Path(__file__).resolve().parent.parent))
    if out.returncode != 0:
        raise RuntimeError(f"probe subprocess failed:\n{out.stderr[-2000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def run(smoke: bool = False, size_mb: float = 0.25, probe_kb: int = 16,
        iters: int = 3, write_table: str | None = None):
    from repro.core import tuning

    table, rows = tuning.autotune(size_mb=size_mb, smoke=smoke,
                                  iters=1 if smoke else iters)
    if write_table:
        merged = tuning.merge_tables(tuning.load_table(write_table), table)
        path = tuning.save_table(merged, write_table)
        print(f"# wrote tuned-defaults table {path}", flush=True)

    # cold-start probe trio around one temp cache dir
    with tempfile.TemporaryDirectory(prefix="repro-jit-cache-") as d:
        _run_probe(d, probe_kb)                      # populate
        warm = _run_probe(d, probe_kb)               # compile = disk load
        cold = _run_probe("", probe_kb)              # no cache: full compile
    speedup = cold["total_ms"] / max(warm["total_ms"], 1e-9)
    rows += [
        ("autotune/compile_cold_ms/no_cache", cold["total_ms"],
         "sum over codecs, fresh process"),
        ("autotune/compile_cold_ms/with_cache", warm["total_ms"],
         "sum over codecs, fresh process + persistent cache"),
        ("autotune/compile_cache_speedup", round(speedup, 2),
         "second-process cold start, no-cache / with-cache"),
    ]
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--size-mb", type=float, default=0.25)
    ap.add_argument("--probe-kb", type=int, default=16)
    ap.add_argument("--out", default=None, help="also write a JSON artifact")
    ap.add_argument("--write-table", default=None,
                    help="merge autotune winners into this tuned-defaults "
                         "JSON (e.g. src/repro/core/tuned_defaults.json)")
    ap.add_argument("--probe", default=None, nargs="?", const="",
                    help=argparse.SUPPRESS)   # internal subprocess entry
    args = ap.parse_args()

    if args.probe is not None:
        print(json.dumps(_probe(args.probe, args.probe_kb)))
        return

    rows = run(smoke=args.smoke, size_mb=args.size_mb,
               probe_kb=args.probe_kb, write_table=args.write_table)
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value},{derived}")
    if args.out:
        from benchmarks.common import write_bench_json
        cfg = {"smoke": bool(args.smoke), "size_mb": args.size_mb,
               "probe_kb": args.probe_kb}
        print(f"# wrote {write_bench_json(args.out, 'autotune', cfg, rows)}")


if __name__ == "__main__":
    main()
