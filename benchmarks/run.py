"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--size-mb 1.0] [--only X]

Prints ``name,value,derived`` CSV rows:
  throughput.py       -> Fig. 7 (absolute) + Fig. 8 (speedups)
  ablations.py        -> §V-E (all-thread vs single-thread)
                         §V-F (warp vs block provisioning + pool sweep)
  ratios.py           -> Table V (compression ratios, symbol lengths)
  roofline_report.py  -> §Roofline terms from the dry-run artifacts
  batched.py          -> launches-per-restore + throughput, batched vs
                         per-blob decode (core.batch scheduler)
  serving.py          -> open-loop multi-tenant DecompressionService:
                         dispatch amplification, latency percentiles,
                         cache hit rate
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--size-mb", type=float, default=0.25,
                help="per-dataset size; 0.25 keeps the full suite ~10 min on CPU")
    ap.add_argument("--only", default=None,
                    help="throughput|ablation_decode|ablation_unit|ratios|"
                         "roofline|batched|serving")
    args = ap.parse_args()

    from benchmarks import (ablations, batched, ratios, roofline_report,
                            serving, throughput)
    suites = {
        "throughput": lambda: throughput.run(args.size_mb),
        "ablation_decode": lambda: ablations.run_decode_ablation(
            min(args.size_mb, 0.5)),
        "ablation_unit": lambda: ablations.run_unit_ablation(
            min(args.size_mb, 0.5)),
        "ratios": lambda: ratios.run(args.size_mb),
        "roofline": roofline_report.run,
        "batched": lambda: batched.run(
            n_arrays=12, kb_per_array=max(8, int(args.size_mb * 64))),
        "serving": lambda: serving.run(
            n_requests=64, n_tenants=4, n_unique=16,
            kb_per_blob=max(8, int(args.size_mb * 32))),
    }
    if args.only:
        suites = {args.only: suites[args.only]}

    print("name,value,derived")
    ok = True
    for sname, fn in suites.items():
        t0 = time.time()
        try:
            for name, value, derived in fn():
                print(f"{name},{value},{derived}")
        except Exception as e:  # pragma: no cover
            ok = False
            print(f"{sname}/ERROR,{type(e).__name__},{e}", file=sys.stderr)
        print(f"_suite/{sname}/seconds,{time.time()-t0:.1f},", flush=True)
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
