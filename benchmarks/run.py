"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--size-mb 1.0] [--only X]
                                            [--all] [--smoke] [--out-dir D]

Prints ``name,value,derived`` CSV rows:
  throughput.py       -> Fig. 7 (absolute) + Fig. 8 (speedups)
  ablations.py        -> §V-E (all-thread vs single-thread)
                         §V-F (warp vs block provisioning + pool sweep)
  ratios.py           -> Table V (compression ratios, symbol lengths)
  roofline_report.py  -> §Roofline terms from the dry-run artifacts
  batched.py          -> launches-per-restore + throughput, batched vs
                         per-blob decode (core.batch scheduler)
  serving.py          -> open-loop multi-tenant DecompressionService:
                         dispatch amplification, latency percentiles,
                         cache hit rate
  device_resident.py  -> host-round-trip vs device-resident decode→consume
                         (transfer counts + throughput)
  sharded.py          -> single- vs 8-virtual-device mesh decode
                         (execute_sharded) + per-device dispatch counts
                         (runs in a forced-device-count subprocess)
  store.py            -> tiered-blob-store overlap efficiency: prefetch-
                         streamed vs serial load-then-decode vs all-in-RAM,
                         exactly-once paging + watermark eviction counts

``--all`` additionally writes one ``BENCH_<suite>.json`` per suite (shared
schema ``{name, config, metrics, timestamp}`` — see
``common.write_bench_json``) into ``--out-dir`` (default: repo root), which
CI uploads as a single perf-trajectory artifact.  ``--smoke`` shrinks every
suite to CI-friendly sizes.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path


def build_suites(args) -> dict:
    """{suite: (config_dict, thunk)} — the thunk returns CSV rows."""
    from benchmarks import (ablations, autotune, batched, collectives,
                            device_resident, ratios, roofline_report,
                            serving, sharded, store, throughput)
    size_mb = 0.05 if args.smoke else args.size_mb
    batched_cfg = ({"n_arrays": 8, "kb_per_array": 8, "iters": 1}
                   if args.smoke else
                   {"n_arrays": 12,
                    "kb_per_array": max(8, int(args.size_mb * 64)),
                    "iters": 3})
    serving_cfg = ({"n_requests": 40, "n_tenants": 4, "n_unique": 10,
                    "kb_per_blob": 8, "rate_per_tenant": 200.0}
                   if args.smoke else
                   {"n_requests": 64, "n_tenants": 4, "n_unique": 16,
                    "kb_per_blob": max(8, int(args.size_mb * 32))})
    device_cfg = ({"n_layers": 2, "k": 128, "n": 128, "iters": 1}
                  if args.smoke else {"n_layers": 4, "iters": 3})
    sharded_cfg = ({"n_arrays": 4, "kb_per_array": 8, "iters": 1, "ndev": 8}
                   if args.smoke else
                   {"n_arrays": 8,
                    "kb_per_array": max(16, int(args.size_mb * 64)),
                    "iters": 3, "ndev": 8})
    autotune_cfg = ({"smoke": True, "size_mb": 0.05, "probe_kb": 8}
                    if args.smoke else
                    {"smoke": False, "size_mb": min(size_mb, 0.25),
                     "probe_kb": 16})
    store_cfg = ({"n_leaves": 15, "kb_per_leaf": 128, "window": 3,
                  "read_delay_ms": 6.0, "iters": 3}
                 if args.smoke else
                 {"n_leaves": 16, "kb_per_leaf": max(128, int(args.size_mb * 512)),
                  "window": 4, "read_delay_ms": 5.0, "iters": 3})
    collectives_cfg = ({"steps": 12, "outer_every": 4, "batch": 2,
                        "seq": 64, "link_rtt_ms": 40.0, "topk_frac": 0.01}
                       if args.smoke else
                       {"steps": 24, "outer_every": 8, "batch": 2,
                        "seq": 64, "link_rtt_ms": 40.0, "topk_frac": 0.01})
    return {
        "throughput": ({"size_mb": size_mb},
                       lambda: throughput.run(size_mb)),
        "ablation_decode": ({"size_mb": min(size_mb, 0.5)},
                            lambda: ablations.run_decode_ablation(
                                min(size_mb, 0.5))),
        "ablation_unit": ({"size_mb": min(size_mb, 0.5)},
                          lambda: ablations.run_unit_ablation(
                              min(size_mb, 0.5))),
        "ratios": ({"size_mb": size_mb}, lambda: ratios.run(size_mb)),
        "roofline": ({}, roofline_report.run),
        "batched": (batched_cfg, lambda: batched.run(**batched_cfg)),
        "serving": (serving_cfg, lambda: serving.run(**serving_cfg)),
        "device": (device_cfg, lambda: device_resident.run(**device_cfg)),
        "sharded": (sharded_cfg, lambda: sharded.run(**sharded_cfg)),
        "autotune": (autotune_cfg, lambda: autotune.run(**autotune_cfg)),
        "store": (store_cfg, lambda: store.run(**store_cfg)),
        "collectives": (collectives_cfg,
                        lambda: collectives.run(**collectives_cfg)),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--size-mb", type=float, default=0.25,
                help="per-dataset size; 0.25 keeps the full suite ~10 min on CPU")
    ap.add_argument("--only", default=None,
                    help="throughput|ablation_decode|ablation_unit|ratios|"
                         "roofline|batched|serving|device|sharded|autotune|"
                         "store|collectives")
    ap.add_argument("--all", action="store_true",
                    help="write one BENCH_<suite>.json per suite "
                         "(shared schema) into --out-dir")
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizes: every suite finishes in seconds")
    ap.add_argument("--out-dir", default=".",
                    help="where --all writes the BENCH_*.json artifacts")
    ap.add_argument("--update-baselines", action="store_true",
                    help="also write each suite's artifact into "
                         "benchmarks/baselines/ (the committed reference "
                         "scripts/check_bench.py gates CI against)")
    ap.add_argument("--compile-cache", nargs="?", const=True, default=None,
                    metavar="DIR",
                    help="enable the persistent jit compilation cache "
                         "(tuning.enable_compile_cache) before any suite "
                         "runs; optional dir, default dir when given bare")
    args = ap.parse_args()

    if args.compile_cache:
        from repro.core import tuning
        path = tuning.enable_compile_cache(
            None if args.compile_cache is True else args.compile_cache)
        print(f"# compile cache: {path}", flush=True)

    from benchmarks.common import write_bench_json
    suites = build_suites(args)
    if args.only:
        suites = {args.only: suites[args.only]}
    baseline_dir = Path(__file__).resolve().parent / "baselines"

    print("name,value,derived")
    ok = True
    for sname, (config, fn) in suites.items():
        t0 = time.time()
        try:
            rows = list(fn())
            for name, value, derived in rows:
                print(f"{name},{value},{derived}")
            if args.all:
                cfg = dict(config, smoke=bool(args.smoke))
                out = write_bench_json(
                    Path(args.out_dir) / f"BENCH_{sname}.json",
                    sname, cfg, rows)
                print(f"# wrote {out}", flush=True)
            if args.update_baselines:
                cfg = dict(config, smoke=bool(args.smoke))
                out = write_bench_json(
                    baseline_dir / f"BENCH_{sname}.json", sname, cfg, rows)
                print(f"# wrote baseline {out}", flush=True)
        except Exception as e:  # pragma: no cover
            ok = False
            print(f"{sname}/ERROR,{type(e).__name__},{e}", file=sys.stderr)
        print(f"_suite/{sname}/seconds,{time.time()-t0:.1f},", flush=True)
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
