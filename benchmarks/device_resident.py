"""Host-round-trip vs device-resident decode→consume (the ISSUE-4 metric).

The workload is quantized serving: N layers of (K, N) int8 weights stored
compressed, consumed by the fused dequant matmul.  Two pipelines over the
SAME blobs:

  * host round-trip — the pre-tentpole path: batched decode, ``np``
    reassembly on host, re-upload, then matmul.  Pays the uncompressed
    output bandwidth twice plus a blocking sync per group.
  * device-resident — ``api.decompress_many(..., device_out=True)`` with
    the zero-point epilogue fused into the decode dispatch, fed straight
    into ``dequant_matmul``.  Host transfers on the decode path: zero,
    counted via ``transfers.count_host_transfers`` (the funnel every
    sanctioned d2h materialization crosses) and verified by running the
    steady-state pass inside ``jax.transfer_guard("disallow")``
    (``transfers.no_host_transfers``).

    PYTHONPATH=src python -m benchmarks.device_resident [--smoke] [--out F]

Emits ``name,value,derived`` CSV rows (benchmarks/run.py convention) and,
with --out, the CI artifact BENCH_device.json (shared schema).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import write_bench_json
from repro.core import api, batch, transfers
from repro.core.engine import CodagEngine, EngineConfig
from repro.kernels import dequant_matmul as dqm

ZERO_POINT = 8


def build_weights(n_layers: int, m: int, k: int, n: int, seed: int = 0):
    """Quantized weight stack + activations: low-magnitude int8 (|q| < 8,
    the post-training-quantization shape bitpack exploits: 5 bits/weight)."""
    rng = np.random.default_rng(seed)
    qs = [rng.integers(-ZERO_POINT, ZERO_POINT, (k, n)).astype(np.int8)
          for _ in range(n_layers)]
    scales = [rng.uniform(0.01, 0.1, (1, n)).astype(np.float32)
              for _ in range(n_layers)]
    x = rng.normal(size=(m, k)).astype(np.float32)
    cas = [dqm.compress_weights(q, "bitpack", zero_point=ZERO_POINT)
           for q in qs]
    return qs, scales, x, cas


def _median(fn, iters: int) -> float:
    fn()  # warmup (jit trace / staging)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def run(n_layers: int = 4, m: int = 128, k: int = 256, n: int = 256,
        iters: int = 3, seed: int = 0):
    qs, scales, x, cas = build_weights(n_layers, m, k, n, seed)
    engine = CodagEngine(EngineConfig())
    x_dev = jnp.asarray(x)
    s_dev = [jnp.asarray(s) for s in scales]
    weight_bytes = sum(q.nbytes for q in qs)
    comp_bytes = sum(ca.compressed_bytes for ca in cas)

    def host_round_trip():
        outs = []
        # decode lands on host (stored uint8), zero-point correction and
        # re-upload happen per layer — the pre-tentpole consumer shape
        for ca, s in zip(cas, s_dev):
            stored = api.decompress(ca, engine)            # device -> host
            q = (stored.astype(np.int16) - ZERO_POINT).astype(np.int8)
            outs.append(dqm.dequant_matmul(
                x_dev, jnp.asarray(q), s, interpret=True)) # host -> device
        return jax.block_until_ready(outs)

    epi, operands = dqm.weight_epilogue(ZERO_POINT)
    plan = batch.BatchPlan.build([b for ca in cas for b in ca.blobs]).stage()

    def device_resident():
        dev_qs = plan.execute_device(engine, epilogue=epi,
                                     epilogue_operands=operands)
        return jax.block_until_ready(
            [dqm.dequant_matmul(x_dev, q, s, interpret=True)
             for q, s in zip(dev_qs, s_dev)])

    # correctness first: both paths equal the uncompressed oracle
    want = [np.asarray(dqm.ref_dequant_matmul(
        x_dev, jnp.asarray(q), s)) for q, s in zip(qs, s_dev)]
    for w, a, b in zip(want, host_round_trip(), device_resident()):
        np.testing.assert_allclose(w, np.asarray(a), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(w, np.asarray(b), rtol=1e-4, atol=1e-5)

    with transfers.count_host_transfers() as host_cnt:
        t_host = _median(host_round_trip, iters)
    with transfers.count_host_transfers() as dev_cnt:
        t_dev = _median(device_resident, iters)
    # the acceptance check: the steady-state device pass completes with the
    # transfer guard armed (raises on any host materialization)
    with transfers.no_host_transfers():
        device_resident()

    per_host = host_cnt["d2h"] / (iters + 1)     # +1 warmup
    rows = [
        ("device/n_layers", n_layers, ""),
        ("device/weight_MB", weight_bytes / 1e6, ""),
        ("device/compression_ratio", comp_bytes / max(1, weight_bytes), ""),
        ("device/host_transfers_per_iter/host_path", per_host, ""),
        ("device/host_transfers_per_iter/device_path",
         dev_cnt["d2h"] / (iters + 1), "guard-verified 0"),
        ("device/host_bytes_per_iter/host_path",
         host_cnt["bytes"] / (iters + 1), ""),
        ("device/latency_ms/host_path", t_host * 1e3, ""),
        ("device/latency_ms/device_path", t_dev * 1e3, ""),
        ("device/throughput_MBps/host_path",
         weight_bytes / t_host / 1e6, ""),
        ("device/throughput_MBps/device_path",
         weight_bytes / t_dev / 1e6, f"{t_host / t_dev:.2f}x host path"),
        ("device/speedup", t_host / t_dev, ""),
    ]
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI: finishes in well under a minute")
    ap.add_argument("--n-layers", type=int, default=4)
    ap.add_argument("--m", type=int, default=128)
    ap.add_argument("--k", type=int, default=256)
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--out", default=None, help="also write a JSON artifact")
    args = ap.parse_args()
    if args.smoke:
        args.n_layers, args.k, args.n, args.iters = 2, 128, 128, 1

    rows = run(args.n_layers, args.m, args.k, args.n, args.iters)
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value},{derived}")

    if args.out:
        cfg = {"n_layers": args.n_layers, "m": args.m, "k": args.k,
               "n": args.n, "iters": args.iters, "smoke": bool(args.smoke)}
        print(f"# wrote {write_bench_json(args.out, 'device', cfg, rows)}")


if __name__ == "__main__":
    main()
