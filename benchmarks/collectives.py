"""Compressed collectives: bytes-on-wire, sync/compute overlap, loss parity.

The collective-plane claim end to end: DiLoCo outer syncs that move
registry-codec compressed bytes (``distributed/collectives.py``) and decode
through ``plan.dispatch`` with fused dequant→reduce epilogues should cut
inter-pod wire traffic by ~4x (int8 wire) / ~20x+ (top-k 1% + bitmap)
versus an f32 ring all-reduce, hide most of the collective behind the next
window's inner steps (``OuterSyncPipeline``), and match the uncompressed
loss trajectory.  This suite runs three short ``train_lm`` runs on a
(2 pod x 4 data) mesh of 8 virtual CPU devices — uncompressed baseline,
int8 wire (+ wire-faithful grad compressor), top-k wire — and reports:

  * ``wire_ratio/{int8,topk}`` — EXACT bytes-on-wire reduction for one
    outer sync of the model's param tree (geometry-derived, deterministic;
    the estimator and the device encoder share one chunk layout),
  * ``overlap_frac`` — fraction of measured collective time (with an
    injected inter-pod link RTT) hidden behind inner steps,
  * ``loss/*`` + ``tok_s/*`` — end-to-end loss parity and step throughput.

``--check`` gates the acceptance bars: int8 wire >= 3.5x, top-k >= 20x,
overlap >= 50%, compressed loss within 5% of the baseline.

Because device count must be fixed before jax initializes, the parent
``run()`` spawns a child under ``XLA_FLAGS=--xla_force_host_platform_
device_count=8`` and parses its CSV rows.

    PYTHONPATH=src python -m benchmarks.collectives [--smoke] [--check]
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

INT8_RATIO_BAR = 3.5
TOPK_RATIO_BAR = 20.0
OVERLAP_BAR = 0.5
LOSS_TOL = 0.05


def _child(steps: int, outer_every: int, batch: int, seq: int,
           link_rtt_ms: float, topk_frac: float) -> list:
    import numpy as np

    from repro.launch import train as train_lib

    def run_one(extra):
        argv = ["--preset", "tiny", "--steps", str(steps),
                "--batch", str(batch), "--seq", str(seq),
                "--diloco", "2", "--outer-every", str(outer_every),
                "--link-rtt", str(link_rtt_ms / 1e3), "--log-every", "0",
                ] + extra
        return train_lib.run_training(train_lib.build_parser()
                                      .parse_args(argv))

    base = run_one(["--outer-wire", "none"])
    int8 = run_one(["--outer-wire", "int8", "--grad-int8"])
    topk = run_one(["--topk", str(topk_frac)])

    def tail_loss(m):
        k = max(1, len(m["losses"]) // 4)
        return float(np.mean(m["losses"][-k:]))

    def tok_s(m):
        return m["tokens_per_step"] * len(m["losses"]) / m["seconds"]

    ov = int8["overlap"]
    rows = [
        ("collectives/ndev", 8, ""),
        ("collectives/n_pods", 2, ""),
        ("collectives/outer_every", outer_every, ""),
        ("collectives/wire_ratio/int8", int8["wire"]["ratio"], ""),
        ("collectives/wire_ratio/topk", topk["wire"]["ratio"], ""),
        ("collectives/wire_MB/f32_ring", int8["wire"]["f32_ring_bytes"] / 1e6,
         ""),
        ("collectives/wire_MB/int8", int8["wire"]["wire_bytes"] / 1e6, ""),
        ("collectives/wire_MB/topk", topk["wire"]["wire_bytes"] / 1e6, ""),
        ("collectives/overlap_frac", ov["overlap_frac"], ""),
        ("collectives/syncs", ov["syncs"], ""),
        ("collectives/collective_s", ov["collective_s"], ""),
        ("collectives/sync_wait_s", ov["wait_s"], ""),
        ("collectives/loss/baseline", tail_loss(base), ""),
        ("collectives/loss/int8", tail_loss(int8),
         tail_loss(int8) / tail_loss(base)),
        ("collectives/loss/topk", tail_loss(topk),
         tail_loss(topk) / tail_loss(base)),
        ("collectives/tok_s/baseline", tok_s(base), ""),
        ("collectives/tok_s/int8", tok_s(int8), ""),
        ("collectives/tok_s/topk", tok_s(topk), ""),
    ]
    return rows


def run(steps: int = 24, outer_every: int = 8, batch: int = 2, seq: int = 64,
        link_rtt_ms: float = 40.0, topk_frac: float = 0.01) -> list:
    """Spawn the fixed-device-count child and parse its CSV rows back."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src") + os.pathsep + _ROOT
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.collectives", "--as-child",
         "--steps", str(steps), "--outer-every", str(outer_every),
         "--batch", str(batch), "--seq", str(seq),
         "--link-rtt-ms", str(link_rtt_ms), "--topk-frac", str(topk_frac)],
        capture_output=True, text=True, env=env, cwd=_ROOT, timeout=1200)
    if r.returncode != 0:
        raise RuntimeError(
            f"collectives bench child failed:\n{r.stderr[-4000:]}")
    rows = []
    for line in r.stdout.splitlines():
        parts = line.strip().split(",")
        if len(parts) == 3 and parts[0].startswith("collectives/"):
            name, value, derived = parts
            rows.append((name, float(value), derived))
    return rows


def check(rows: list) -> list:
    """Acceptance bars; returns a list of failure strings (empty = pass)."""
    m = {name: value for name, value, _ in rows}
    problems = []
    if m["collectives/wire_ratio/int8"] < INT8_RATIO_BAR:
        problems.append(
            f"int8 wire ratio {m['collectives/wire_ratio/int8']:.2f} "
            f"< {INT8_RATIO_BAR}")
    if m["collectives/wire_ratio/topk"] < TOPK_RATIO_BAR:
        problems.append(
            f"topk wire ratio {m['collectives/wire_ratio/topk']:.2f} "
            f"< {TOPK_RATIO_BAR}")
    if m["collectives/overlap_frac"] < OVERLAP_BAR:
        problems.append(
            f"overlap_frac {m['collectives/overlap_frac']:.2f} "
            f"< {OVERLAP_BAR}")
    lb, li = m["collectives/loss/baseline"], m["collectives/loss/int8"]
    if li > lb * (1.0 + LOSS_TOL):
        problems.append(f"int8 loss {li:.4f} > baseline {lb:.4f} "
                        f"* {1 + LOSS_TOL}")
    return problems


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI: finishes in a couple minutes")
    ap.add_argument("--check", action="store_true",
                    help="gate the acceptance bars (wire ratios, overlap, "
                         "loss parity); exit 1 on failure")
    ap.add_argument("--as-child", action="store_true",
                    help=argparse.SUPPRESS)   # internal: run inside the
    #                                           forced-device-count process
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--outer-every", type=int, default=8)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--link-rtt-ms", type=float, default=40.0)
    ap.add_argument("--topk-frac", type=float, default=0.01)
    ap.add_argument("--out", default=None, help="also write a JSON artifact")
    args = ap.parse_args()
    if args.smoke:
        args.steps, args.outer_every, args.seq = 12, 4, 64

    if args.as_child:
        rows = _child(args.steps, args.outer_every, args.batch, args.seq,
                      args.link_rtt_ms, args.topk_frac)
    else:
        rows = run(args.steps, args.outer_every, args.batch, args.seq,
                   args.link_rtt_ms, args.topk_frac)
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value},{derived}")

    if args.out and not args.as_child:
        from benchmarks.common import write_bench_json
        cfg = {"steps": args.steps, "outer_every": args.outer_every,
               "batch": args.batch, "seq": args.seq,
               "link_rtt_ms": args.link_rtt_ms,
               "topk_frac": args.topk_frac, "smoke": bool(args.smoke)}
        print(f"# wrote {write_bench_json(args.out, 'collectives', cfg, rows)}")

    if args.check and not args.as_child:
        problems = check(rows)
        for p in problems:
            print(f"COLLECTIVES CHECK FAILED: {p}", file=sys.stderr)
        if problems:
            raise SystemExit(1)
        print("collectives check ok: wire ratios, overlap, and loss parity "
              "within bars")


if __name__ == "__main__":
    main()
