"""Fig. 7 + Fig. 8 analogue: decompression throughput, CODAG vs baseline.

CODAG      = warp-unit provisioning (one chunk per independent stream) with
             all-thread (vectorized two-phase) decoding.
baseline   = RAPIDS-like provisioning: a fixed pool of block-level
             decompression units, each serially looping its chunk share,
             with single-thread (leader) decoding — the Fig. 1a structure.

CPU wall-clock is a proxy for the A100 numbers (same code lowered for TPU);
the quantity mirrored from the paper is the RELATIVE speedup structure:
large gains for RLE v1/v2, small for deflate (decode-serial-bound).
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import compressed_corpus, geomean, timeit
from repro.core import format as fmt
from repro.core import registry
from repro.core.engine import CodagEngine, EngineConfig

CODECS = (fmt.RLE_V1, fmt.RLE_V2, fmt.TDEFLATE)

ENGINES = {
    "codag": EngineConfig(unit="warp", all_thread=True, backend="xla"),
    "baseline": EngineConfig(unit="block", n_units=8, all_thread=False,
                             backend="xla"),
}


def _bench_blob(engine: CodagEngine, blob) -> float:
    dev = {k: jnp.asarray(v) for k, v in blob.to_device().items()}
    bits = registry.get(blob.codec).static_bits(blob)

    def run():
        return engine.decompress_chunks(dev, codec=blob.codec,
                                        width=blob.width,
                                        chunk_elems=blob.chunk_elems,
                                        bits=bits)

    sec = timeit(run)
    return blob.uncompressed_bytes / sec   # output bytes/s (paper's metric)


def run(size_mb: float = 1.0, iters: int = 3):
    corpus = compressed_corpus(size_mb, CODECS)
    rows = []
    for codec in CODECS:
        speedups = []
        for name, ca in corpus[codec].items():
            tps = {}
            for ename, ecfg in ENGINES.items():
                eng = CodagEngine(ecfg)
                tp = sum(_bench_blob(eng, b) for b in ca.blobs) / len(ca.blobs)
                tps[ename] = tp
            sp = tps["codag"] / tps["baseline"]
            speedups.append(sp)
            rows.append((f"throughput/{codec}/{name}/codag_MBps",
                         tps["codag"] / 1e6, sp))
            rows.append((f"throughput/{codec}/{name}/baseline_MBps",
                         tps["baseline"] / 1e6, sp))
        rows.append((f"throughput/{codec}/geomean_speedup",
                     geomean(speedups), geomean(speedups)))
    return rows
