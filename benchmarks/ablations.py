"""§V-E and §V-F ablations, driven by the codec registry.

ablation_decode (§V-E): all-thread (vectorized two-phase expansion) vs
single-thread decoding, both at warp-unit provisioning.  Paper: all-thread
wins 1.17x (RLE) / 1.19x (deflate) on A100; on the CPU proxy the gap is far
larger because a scalar while-loop step is the worst case for both.

ablation_unit (§V-F): warp-unit vs block-unit provisioning (both all-thread)
+ a pool-size sweep — the paper's finding that finer decompression units win
because more independent streams are in flight.

The codec matrix is ``registry.names()`` — every registered codec (including
any future plugin) is measured on its own ``demo_data`` workload, so a new
codec lands in the ablation suite with zero changes here.

    PYTHONPATH=src python -m benchmarks.ablations [--smoke] [--out FILE.json]

Emits ``name,value,derived`` CSV rows and, with --out, a JSON artifact (the
CI perf-trajectory file BENCH_ablations.json).
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp

from benchmarks.common import (codec_matrix, demo_corpus, geomean, timeit,
                               write_bench_json)
from repro.core import registry
from repro.core.engine import CodagEngine, EngineConfig


def _tp(engine_cfg: EngineConfig, ca) -> float:
    eng = CodagEngine(engine_cfg)
    total = 0.0
    for blob in ca.blobs:
        dev = {k: jnp.asarray(v) for k, v in blob.to_device().items()}
        bits = registry.get(blob.codec).static_bits(blob)

        def run():
            return eng.decompress_chunks(dev, codec=blob.codec,
                                         width=blob.width,
                                         chunk_elems=blob.chunk_elems,
                                         bits=bits)

        total += blob.uncompressed_bytes / timeit(run)
    return total / len(ca.blobs)


def run_decode_ablation(size_mb: float = 0.5):
    corpus = demo_corpus(size_mb)
    rows = []
    sps = []
    for name, ca in corpus.items():
        tp_all = _tp(EngineConfig(unit="warp", all_thread=True), ca)
        tp_one = _tp(EngineConfig(unit="warp", all_thread=False), ca)
        sps.append(tp_all / tp_one)
        rows.append((f"ablation_decode/{name}/allthread_over_single",
                     tp_all / tp_one, tp_all / 1e6))
    rows.append(("ablation_decode/geomean", geomean(sps), len(sps)))
    return rows


def run_unit_ablation(size_mb: float = 0.5):
    corpus = demo_corpus(size_mb)
    rows = []
    sps = []
    for name, ca in corpus.items():
        tp_warp = _tp(EngineConfig(unit="warp", all_thread=True), ca)
        tp_block = _tp(EngineConfig(unit="block", n_units=8,
                                    all_thread=True), ca)
        sps.append(tp_warp / tp_block)
        rows.append((f"ablation_unit/{name}/warp_over_block",
                     tp_warp / tp_block, tp_warp / 1e6))
    rows.append(("ablation_unit/geomean", geomean(sps), len(sps)))
    # pool-size sweep on one run-heavy codec (finer units -> more streams)
    ca = corpus[codec_matrix()[0]]
    for n_units in (1, 4, 16, 64):
        tp = _tp(EngineConfig(unit="block", n_units=n_units,
                              all_thread=True), ca)
        rows.append((f"ablation_unit/{codec_matrix()[0]}/pool{n_units}_MBps",
                     tp / 1e6, n_units))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI: finishes in a few minutes")
    ap.add_argument("--size-mb", type=float, default=0.5)
    ap.add_argument("--out", default=None, help="also write a JSON artifact")
    args = ap.parse_args()
    if args.smoke:
        args.size_mb = 0.03

    rows = run_decode_ablation(args.size_mb) + run_unit_ablation(args.size_mb)
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value},{derived}")

    if args.out:
        cfg = {"size_mb": args.size_mb, "smoke": bool(args.smoke),
               "codecs": list(codec_matrix())}
        print(f"# wrote {write_bench_json(args.out, 'ablations', cfg, rows)}")


if __name__ == "__main__":
    main()
