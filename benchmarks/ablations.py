"""§V-E and §V-F ablations.

ablation_decode (§V-E): all-thread (vectorized two-phase expansion) vs
single-thread decoding, both at warp-unit provisioning.  Paper: all-thread
wins 1.17x (RLE) / 1.19x (deflate) on A100; on the CPU proxy the gap is far
larger because a scalar while-loop step is the worst case for both.

ablation_unit (§V-F): warp-unit vs block-unit provisioning (both all-thread)
+ a pool-size sweep — the paper's finding that finer decompression units win
because more independent streams are in flight.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import compressed_corpus, geomean, timeit
from repro.core import format as fmt
from repro.core.engine import CodagEngine, EngineConfig

CODECS = (fmt.RLE_V1, fmt.RLE_V2, fmt.TDEFLATE)
DATASETS_SMALL = ("MC0", "TPC", "HRG")   # paper's §V-E uses MC0/TPC


def _tp(engine_cfg: EngineConfig, ca) -> float:
    eng = CodagEngine(engine_cfg)
    total = 0.0
    for blob in ca.blobs:
        dev = {k: jnp.asarray(v) for k, v in blob.to_device().items()}

        def run():
            return eng.decompress_chunks(dev, codec=blob.codec,
                                         width=blob.width,
                                         chunk_elems=blob.chunk_elems)

        total += blob.uncompressed_bytes / timeit(run)
    return total / len(ca.blobs)


def run_decode_ablation(size_mb: float = 0.5):
    corpus = compressed_corpus(size_mb, CODECS)
    rows = []
    for codec in CODECS:
        sps = []
        for name in DATASETS_SMALL:
            ca = corpus[codec][name]
            tp_all = _tp(EngineConfig(unit="warp", all_thread=True), ca)
            tp_one = _tp(EngineConfig(unit="warp", all_thread=False), ca)
            sps.append(tp_all / tp_one)
            rows.append((f"ablation_decode/{codec}/{name}/allthread_over_single",
                         tp_all / tp_one, tp_all / 1e6))
        rows.append((f"ablation_decode/{codec}/geomean",
                     geomean(sps), geomean(sps)))
    return rows


def run_unit_ablation(size_mb: float = 0.5):
    corpus = compressed_corpus(size_mb, CODECS)
    rows = []
    for codec in CODECS:
        sps = []
        for name in DATASETS_SMALL:
            ca = corpus[codec][name]
            tp_warp = _tp(EngineConfig(unit="warp", all_thread=True), ca)
            tp_block = _tp(EngineConfig(unit="block", n_units=8,
                                        all_thread=True), ca)
            sps.append(tp_warp / tp_block)
            rows.append((f"ablation_unit/{codec}/{name}/warp_over_block",
                         tp_warp / tp_block, tp_warp / 1e6))
        rows.append((f"ablation_unit/{codec}/geomean",
                     geomean(sps), geomean(sps)))
        # pool-size sweep on one dataset (finer units -> more streams)
        ca = corpus[codec]["MC0"]
        for n_units in (1, 4, 16, 64):
            tp = _tp(EngineConfig(unit="block", n_units=n_units,
                                  all_thread=True), ca)
            rows.append((f"ablation_unit/{codec}/MC0/pool{n_units}_MBps",
                         tp / 1e6, n_units))
    return rows
