"""§Roofline report: renders the dry-run artifact table (one row per
arch x shape x mesh cell) from experiments/dryrun_results.json."""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path("experiments/dryrun_results.json")


def run(path: str = str(RESULTS)):
    p = Path(path)
    rows = []
    if not p.exists():
        rows.append(("roofline/missing", 0.0, "run repro.launch.dryrun first"))
        return rows
    results = json.loads(p.read_text())
    for key, cell in sorted(results.items()):
        if cell.get("status") == "skipped":
            rows.append((f"roofline/{key}/skipped", 0.0, cell["reason"][:40]))
            continue
        if cell.get("status") != "ok":
            rows.append((f"roofline/{key}/ERROR", -1.0,
                         cell.get("error", "?")[:60]))
            continue
        r = cell["roofline"]
        rows.append((f"roofline/{key}/t_compute_s", r["t_compute_s"],
                     r["dominant"]))
        rows.append((f"roofline/{key}/t_memory_s", r["t_memory_s"],
                     r["dominant"]))
        rows.append((f"roofline/{key}/t_collective_s", r["t_collective_s"],
                     r["dominant"]))
        rows.append((f"roofline/{key}/useful_flops_ratio",
                     r["useful_flops_ratio"], r["mfu_bound"]))
    return rows


def table(path: str = str(RESULTS)) -> str:
    """Human-readable markdown table (used to generate EXPERIMENTS.md)."""
    results = json.loads(Path(path).read_text())
    lines = ["| arch | shape | mesh | t_comp | t_mem | t_coll | dominant "
             "| useful | mfu_bound |",
             "|---|---|---|---|---|---|---|---|---|"]
    for key, cell in sorted(results.items()):
        arch, shape, mesh = key.split("|")[:3]
        if cell.get("status") == "skipped":
            lines.append(f"| {arch} | {shape} | {mesh} | — | — | — | "
                         f"skipped | — | — |")
            continue
        if cell.get("status") != "ok":
            lines.append(f"| {arch} | {shape} | {mesh} | ERR | | | | | |")
            continue
        r = cell["roofline"]
        lines.append(
            f"| {arch} | {shape} | {mesh} | {r['t_compute_s']:.4f} "
            f"| {r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} "
            f"| {r['dominant']} | {r['useful_flops_ratio']:.2f} "
            f"| {r['mfu_bound']:.3f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(table())
