"""Synthetic datasets mirroring the paper's Table IV corpus characteristics.

| name | mirrors | dtype   | structure                                   |
|------|---------|---------|---------------------------------------------|
| MC0  | Mortgage col 0      | uint64 | very long runs (ratio ~0.02)    |
| MC3  | Mortgage col 3      | fp32   | long runs of repeated floats    |
| TPC  | Taxi passenger cnt  | int8   | run len ~1-6, tiny alphabet     |
| TPT  | Taxi payment type   | char   | ~unit runs, 4-symbol alphabet   |
| CD2  | Criteo dense 2      | uint32 | power-law values                |
| TC2  | Twitter COO col 1   | uint64 | sorted ids -> delta-friendly    |
| HRG  | Human ref genome    | char   | ACGTN with repeated motifs      |
"""
from __future__ import annotations

import numpy as np


def build(size_mb: float = 2.0, seed: int = 0):
    rng = np.random.default_rng(seed)
    n8 = int(size_mb * (1 << 20))           # bytes budget per dataset

    def mc0():
        n = n8 // 8
        vals = rng.integers(0, 500, max(4, n // 600)).astype(np.uint64)
        lens = rng.integers(200, 1000, len(vals))
        return np.repeat(vals, lens)[:n]

    def mc3():
        n = n8 // 4
        vals = (rng.normal(3.5, 1.0, max(4, n // 300)).astype(np.float32))
        lens = rng.integers(100, 500, len(vals))
        return np.repeat(vals, lens)[:n]

    def tpc():
        n = n8
        vals = rng.choice(np.arange(1, 7, dtype=np.int8), n,
                          p=[0.72, 0.14, 0.06, 0.04, 0.03, 0.01])
        # short runs: smear
        runs = rng.integers(0, n - 8, n // 6)
        for s in runs[:2000]:
            vals[s:s + int(rng.integers(2, 6))] = vals[s]
        return vals

    def tpt():
        return rng.choice(np.frombuffer(b"1234", np.uint8), n8,
                          p=[0.55, 0.41, 0.03, 0.01])

    def cd2():
        n = n8 // 4
        return np.minimum(rng.zipf(1.5, n), 2 ** 31).astype(np.uint32)

    def tc2():
        n = n8 // 8
        ids = np.sort(rng.integers(0, 2 ** 33, n).astype(np.uint64))
        return ids

    def hrg():
        motif = rng.choice(np.frombuffer(b"ACGT", np.uint8), 400)
        out = np.empty(n8, np.uint8)
        pos = 0
        while pos < n8:
            if rng.random() < 0.3:   # repeated motif
                m = motif[: min(len(motif), n8 - pos)]
            else:
                m = rng.choice(np.frombuffer(b"ACGTN", np.uint8),
                               min(int(rng.integers(50, 300)), n8 - pos),
                               p=[0.29, 0.21, 0.21, 0.28, 0.01])
            out[pos:pos + len(m)] = m
            pos += len(m)
        return out

    return {"MC0": mc0(), "MC3": mc3(), "TPC": tpc(), "TPT": tpt(),
            "CD2": cd2(), "TC2": tc2(), "HRG": hrg()}
