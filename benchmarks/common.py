"""Shared benchmark machinery: timing, dataset/blob caching, codec matrix,
and the one BENCH_*.json artifact schema."""
from __future__ import annotations

import functools
import json
import pickle
import time
from pathlib import Path

import jax
import numpy as np

from repro.core import api, registry
from benchmarks import datasets as ds

CACHE = Path("experiments/.bench_cache")


def write_bench_json(path, name: str, config: dict, rows) -> Path:
    """Write one benchmark artifact in the shared schema.

    Every ``BENCH_*.json`` the suite emits (``benchmarks.run --all`` and
    each module's ``--out``) has the same four top-level keys, so the CI
    perf-trajectory tooling can diff any of them uniformly:

        {"name": ...,       # suite name ("batched", "serving", ...)
         "config": {...},   # the knobs this run used (sizes, counts, smoke)
         "metrics": {...},  # flat metric name -> value (the CSV rows)
         "timestamp": ...}  # UTC ISO-8601
    """
    payload = {
        "name": name,
        "config": dict(config),
        "metrics": {n: v for n, v, _ in rows},
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
    }
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(payload, indent=2))
    return p


def codec_matrix() -> tuple:
    """The registry-complete codec list (checked by CI for completeness)."""
    return tuple(registry.names())


def demo_elems(codec, n_bytes: int) -> int:
    """Element count so ``codec.demo_data`` yields ~n_bytes uncompressed."""
    return max(1024, n_bytes // (1 if codec.byte_stream else 4))


@functools.lru_cache(maxsize=8)
def demo_corpus(size_mb: float, chunk_bytes: int = 16 * 1024, seed: int = 0):
    """{codec: CompressedArray} of codec-appropriate demo data (memoized —
    the host encoders are the slow python part)."""
    rng = np.random.default_rng(seed)
    out = {}
    for name in codec_matrix():
        codec = registry.get(name)
        arr = codec.demo_data(demo_elems(codec, int(size_mb * (1 << 20))), rng)
        out[name] = api.compress(arr, name, chunk_bytes)
    return out


def timeit(fn, *args, iters: int = 3, warmup: int = 1):
    """Median wall time of a jitted callable (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def compressed_corpus(size_mb: float, codecs, chunk_bytes: int = 64 * 1024,
                      seed: int = 0):
    """{codec: {dataset: CompressedArray}} with on-disk cache (tdeflate
    encoding is the slow python part)."""
    CACHE.mkdir(parents=True, exist_ok=True)
    key = f"corpus_{size_mb}_{chunk_bytes}_{seed}_{'-'.join(codecs)}.pkl"
    f = CACHE / key
    if f.exists():
        with open(f, "rb") as fh:
            return pickle.load(fh)
    raw = ds.build(size_mb, seed)
    out = {}
    for codec in codecs:
        out[codec] = {name: api.compress(arr, codec, chunk_bytes)
                      for name, arr in raw.items()}
    with open(f, "wb") as fh:
        pickle.dump(out, fh)
    return out


def geomean(xs):
    xs = np.asarray(list(xs), np.float64)
    return float(np.exp(np.mean(np.log(np.maximum(xs, 1e-12)))))
