"""Tiered-blob-store benchmark: how much backend I/O hides behind decode.

CODAG's characterization (§V) says GPU decompression is compute-bound, so
the compressed bytes' storage I/O should overlap INTO decode rather than
serialize in front of it.  This suite measures that on a checkpoint-shaped
blob set written through a :class:`FilesystemBackend` with an injected
per-read latency (standing in for an object store's RTT — local page
cache would otherwise make the experiment vacuous):

  * ``t_ram_s``    — all blobs pre-loaded in host RAM, decode only: the
                     upper bound no streaming scheme can beat.
  * ``t_serial_s`` — ``stream_windows(lookahead=0)`` on a cold store:
                     every window's reads are paid synchronously before
                     its decode (the load-then-decode baseline).
  * ``t_stream_s`` — ``stream_windows(lookahead=1)`` on a cold store:
                     window i+1's reads ride the prefetch pool while
                     window i decodes.

  overlap_frac    = (t_serial - t_stream) / (t_serial - t_ram)
                    fraction of the serial I/O bill the prefetch hid
                    (1.0 = fully hidden; the CI bar is >= 0.8).
  stream_over_ram = t_stream / t_ram (<= 1.25 is the acceptance bar).

The run is an out-of-core one by construction: the store's host budget is
``host_budget_frac`` of the compressed bytes (``store/over_budget`` = 1.0
asserts the data does NOT fit), so completing bit-exactly also proves
demand paging + release keep residency bounded.  Two deterministic
side-scenarios gate the policy itself: ``store/stream_fetches`` must equal
``store/n_leaves`` (exactly-once paging — the budget fits the pipeline's
1+lookahead windows, so no thrash), and ``store/pressure_evictions``
counts watermark evictions
from a no-release sweep under a tiny budget (must be > 0).

    PYTHONPATH=src python -m benchmarks.store [--smoke] [--check]
                                              [--out FILE.json]

Emits ``name,value,derived`` CSV rows (benchmarks/run.py convention); with
``--check`` exits non-zero when an acceptance bar fails (CI smoke step).
"""
from __future__ import annotations

import argparse
import tempfile
import time

import numpy as np

from benchmarks.common import codec_matrix, demo_elems, write_bench_json
from repro.core import api, registry
from repro.core.engine import CodagEngine, EngineConfig
from repro.core.store import FilesystemBackend, TieredBlobStore


def build_leaves(n_leaves: int, kb_per_leaf: int, chunk_bytes: int,
                 seed: int):
    """Checkpoint-shaped mixed-codec leaves (every registered codec
    contributes, round-robin) -> (arrays, CompressedArrays, keys)."""
    rng = np.random.default_rng(seed)
    codecs = codec_matrix()
    arrays, cas, keys = [], [], []
    for i in range(n_leaves):
        name = codecs[i % len(codecs)]
        codec = registry.get(name)
        arr = codec.demo_data(demo_elems(codec, kb_per_leaf * 1024), rng)
        arrays.append(arr)
        cas.append(api.compress(arr, name, chunk_bytes=chunk_bytes))
        keys.append(f"leaf_{i:04d}.blob")
    return arrays, cas, keys


def _decode_windows(window_iter, engine):
    """The consumer every scenario shares: one decompress_many per window."""
    out = []
    for cas in window_iter:
        out.extend(api.decompress_many(cas, engine))
    return out


def _windows(seq, w):
    return (seq[i:i + w] for i in range(0, len(seq), w))


def run(n_leaves: int = 16, kb_per_leaf: int = 128, window: int = 4,
        read_delay_ms: float = 5.0, host_budget_frac: float = 0.45,
        pressure_budget_frac: float = 0.2, chunk_bytes: int = 4 * 1024,
        lookahead: int = 2, seed: int = 0, iters: int = 3,
        check: bool = False):
    arrays, cas, keys = build_leaves(n_leaves, kb_per_leaf, chunk_bytes,
                                     seed)
    engine = CodagEngine(EngineConfig())
    n_windows = (n_leaves + window - 1) // window

    with tempfile.TemporaryDirectory(prefix="codag_store_bench_") as root:
        # spill every leaf to the disk tier (no injected delay on writes)
        writer = TieredBlobStore(FilesystemBackend(root))
        sizes = [writer.put(k, ca) for k, ca in zip(keys, cas)]
        writer.close()
        comp_bytes = sum(sizes)
        win_bytes = max(sum(w) for w in _windows(sizes, window))
        # exactly-once paging needs room for the current window plus the
        # ``lookahead`` prefetched ones; below that the lookahead's admits
        # evict not-yet-consumed entries (graceful refetch, but it would
        # fail the stream_fetches gate)
        budget = max(int(host_budget_frac * comp_bytes),
                     (1 + max(1, lookahead)) * win_bytes)
        delay_s = read_delay_ms / 1e3

        def cold_store(lookahead_pool: int) -> TieredBlobStore:
            return TieredBlobStore(
                FilesystemBackend(root, read_delay_s=delay_s),
                host_budget_bytes=budget,
                prefetch_workers=max(1, lookahead_pool))

        # warm the jit caches once so no scenario pays compilation
        _decode_windows(_windows(cas, window), engine)

        # -- all-in-RAM upper bound: decode only, blobs already resident
        t_ram = []
        for _ in range(iters):
            t0 = time.perf_counter()
            _decode_windows(_windows(cas, window), engine)
            t_ram.append(time.perf_counter() - t0)
        t_ram = float(np.min(t_ram))

        # -- serial load-then-decode: lookahead=0, cold store per iter
        t_serial = []
        for _ in range(iters):
            with cold_store(1) as st:
                t0 = time.perf_counter()
                _decode_windows(
                    st.stream_windows(keys, window=window, lookahead=0),
                    engine)
                t_serial.append(time.perf_counter() - t0)
        t_serial = float(np.min(t_serial))

        # -- overlapped streaming: pool wide enough for ``lookahead``
        #    windows' fetches to ride in parallel with the current decode.
        #    Depth 2 (default) matters: per-window decode time varies with
        #    the codec mix, and a SHORT window's decode cannot cover the
        #    next window's reads alone — issuing I/O two windows ahead
        #    amortizes it across two decodes.
        t_stream, stream_fetches = [], 0
        for _ in range(iters):
            with cold_store(window * max(1, lookahead)) as st:
                t0 = time.perf_counter()
                decoded_stream = _decode_windows(
                    st.stream_windows(keys, window=window,
                                      lookahead=lookahead),
                    engine)
                t_stream.append(time.perf_counter() - t0)
                s = st.stats()
                stream_fetches = s.backend_fetches
                resident_after = s.host_bytes
        t_stream = float(np.min(t_stream))

        # -- deterministic watermark-pressure sweep: tiny budget, gets
        #    without release -> the watermark must do the evicting
        with TieredBlobStore(
                FilesystemBackend(root),
                host_budget_bytes=max(int(pressure_budget_frac * comp_bytes),
                                      max(sizes)),
                low_watermark=0.5) as st:
            for k in keys:
                st.get(k)
            pressure = st.stats()

    for a, d in zip(arrays, decoded_stream):
        assert np.array_equal(np.asarray(a).reshape(-1),
                              np.asarray(d).reshape(-1)), \
            "streamed decode not bit-exact"

    denom = max(t_serial - t_ram, 1e-9)
    overlap_frac = (t_serial - t_stream) / denom
    stream_over_ram = t_stream / max(t_ram, 1e-9)

    rows = [
        ("store/n_leaves", n_leaves, ""),
        ("store/n_windows", n_windows, ""),
        ("store/comp_MB", round(comp_bytes / 1e6, 4), "backend bytes"),
        ("store/over_budget", float(comp_bytes > budget),
         "1.0 = checkpoint exceeds the host budget (out-of-core run)"),
        ("store/stream_fetches", stream_fetches,
         "== n_leaves: exactly-once paging, no thrash"),
        ("store/stream_resident_bytes", resident_after,
         "tier-1 bytes left after the streamed pass (released windows)"),
        ("store/pressure_evictions", pressure.host_evictions,
         "watermark evictions in the no-release tiny-budget sweep"),
        ("store/t_ram_s", round(t_ram, 4), "all blobs in RAM, decode only"),
        ("store/t_serial_s", round(t_serial, 4),
         "load-then-decode, lookahead=0"),
        ("store/t_stream_s", round(t_stream, 4),
         f"prefetch-overlapped, lookahead={lookahead}"),
        ("store/overlap_frac", round(overlap_frac, 4),
         "fraction of serial I/O hidden behind decode (1.0 = all)"),
        ("store/stream_over_ram", round(stream_over_ram, 4),
         "streaming vs all-in-RAM upper bound (1.0 = I/O fully hidden)"),
    ]

    if check:
        bars = [
            (comp_bytes > budget, "data fits the host budget — not an "
             "out-of-core run; shrink host_budget_frac"),
            (stream_fetches == n_leaves,
             f"paging thrashed: {stream_fetches} fetches for "
             f"{n_leaves} leaves"),
            (pressure.host_evictions > 0, "watermark never evicted under "
             "pressure"),
            (overlap_frac >= 0.8,
             f"prefetch hid only {overlap_frac:.0%} of the serial I/O "
             "(bar: 80%)"),
            (stream_over_ram <= 1.25,
             f"streaming is {stream_over_ram:.2f}x the all-in-RAM bound "
             "(bar: 1.25x)"),
        ]
        failures = [msg for ok, msg in bars if not ok]
        if failures:
            for msg in failures:
                print(f"STORE CHECK FAILED: {msg}")
            raise SystemExit(1)
        print(f"# store check ok: overlap_frac={overlap_frac:.2f} "
              f"stream_over_ram={stream_over_ram:.2f}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI: finishes in seconds")
    ap.add_argument("--check", action="store_true",
                    help="enforce the acceptance bars (exit 1 on failure)")
    ap.add_argument("--n-leaves", type=int, default=16)
    ap.add_argument("--kb-per-leaf", type=int, default=128)
    ap.add_argument("--window", type=int, default=4)
    ap.add_argument("--read-delay-ms", type=float, default=5.0)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--out", default=None, help="also write a JSON artifact")
    args = ap.parse_args()
    if args.smoke:
        args.n_leaves, args.kb_per_leaf = 15, 128
        args.window, args.read_delay_ms, args.iters = 3, 6.0, 3

    rows = run(args.n_leaves, args.kb_per_leaf, args.window,
               args.read_delay_ms, iters=args.iters, check=args.check)
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value},{derived}")

    if args.out:
        cfg = {"n_leaves": args.n_leaves, "kb_per_leaf": args.kb_per_leaf,
               "window": args.window, "read_delay_ms": args.read_delay_ms,
               "iters": args.iters, "smoke": bool(args.smoke)}
        print(f"# wrote {write_bench_json(args.out, 'store', cfg, rows)}")


if __name__ == "__main__":
    main()
