"""Open-loop multi-tenant serving benchmark for the DecompressionService.

The scenario the batch scheduler cannot cover: requests do NOT arrive
together.  ``n_tenants`` producer threads each replay an open-loop Poisson
arrival process (exponential inter-arrivals, submission times fixed up
front, so a slow service cannot slow the offered load — no coordinated
omission) over a shared pool of mixed-codec blobs.  The service coalesces
whatever lands inside each micro-batch window into fused dispatches, and
its content-keyed cache absorbs repeated blobs.

Headline numbers (the ISSUE-3 acceptance metric is the first one):

  * dispatch amplification — engine dispatches / blobs served.  The
    one-dispatch-per-blob baseline is exactly 1.0; coalescing + cache must
    push it below 1.0.
  * request latency p50/p99 (measured from the scheduled arrival time).
  * cache hit rate, blobs/window, dispatches/window.
  * decoded throughput vs. the synchronous per-blob loop.

    PYTHONPATH=src python -m benchmarks.serving [--smoke] [--out FILE.json]

Emits ``name,value,derived`` CSV rows (benchmarks/run.py convention) and,
with --out, the CI artifact BENCH_serving.json.
"""
from __future__ import annotations

import argparse
import threading
import time

import numpy as np

from benchmarks.common import codec_matrix, demo_elems, write_bench_json
from repro.core import api, registry
from repro.core.engine import CodagEngine, EngineConfig
from repro.core.server import DecompressionService
from repro.kernels import ops


# --- backend-compile accounting -------------------------------------------
# One process-wide jax.monitoring listener accumulating XLA backend-compile
# durations; run() reads it by index range to attribute compile time to the
# priming pass.  Registered once (jax has no unregister API).
_compile_secs: list = []
_listener_on = False


def _ensure_compile_listener() -> None:
    global _listener_on
    if _listener_on:
        return
    import jax

    def _cb(event, duration, **kw):
        if "backend_compile" in event:
            _compile_secs.append(duration)

    jax.monitoring.register_event_duration_secs_listener(_cb)
    _listener_on = True


def build_pool(n_unique: int, kb_per_blob: int, chunk_bytes: int, seed: int):
    """Unique mixed-codec blobs (every registered codec contributes)."""
    rng = np.random.default_rng(seed)
    codecs = codec_matrix()
    arrays, blobs = [], []
    for i in range(n_unique):
        name = codecs[i % len(codecs)]
        codec = registry.get(name)
        arr = codec.demo_data(demo_elems(codec, kb_per_blob * 1024), rng)
        ca = api.compress(arr, name, chunk_bytes=chunk_bytes)
        arrays.append(arr)
        blobs.append(ca.blobs[0])
    return arrays, blobs


def build_trace(n_requests: int, n_tenants: int, n_unique: int,
                rate_per_tenant: float, seed: int):
    """Per-tenant (arrival_time, blob_idx) schedules; arrivals are a Poisson
    process per tenant, blob choice uniform over the shared pool (requests >
    unique blobs => repeats => cache hits)."""
    rng = np.random.default_rng(seed + 1)
    per = [n_requests // n_tenants] * n_tenants
    for i in range(n_requests - sum(per)):
        per[i] += 1
    traces = []
    for n in per:
        gaps = rng.exponential(1.0 / rate_per_tenant, n)
        arrivals = np.cumsum(gaps)
        idxs = rng.integers(0, n_unique, n)
        traces.append(list(zip(arrivals.tolist(), idxs.tolist())))
    return traces


def _serve_trace(svc, traces, blobs, arrays):
    """Replay one open-loop pass; returns (lat_ms, dispatches, bytes, secs)."""
    results: list = []
    res_lock = threading.Lock()

    def tenant(trace, t0):
        for t_arr, idx in trace:
            delay = t0 + t_arr - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            rec = {"sched": t0 + t_arr, "idx": idx}
            fut = svc.submit(blobs[idx])
            fut.add_done_callback(
                lambda f, rec=rec: rec.__setitem__(
                    "done", time.perf_counter()))
            with res_lock:
                results.append((fut, rec))

    t_start = time.perf_counter()
    with ops.count_dispatches() as dispatch_log:
        t0 = time.perf_counter() + 0.02     # common epoch for all tenants
        threads = [threading.Thread(target=tenant, args=(tr, t0))
                   for tr in traces]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        outs = [(fut.result(), rec) for fut, rec in results]
    t_serve = time.perf_counter() - t_start

    for out, rec in outs:
        assert np.array_equal(out, arrays[rec["idx"]]), "serving not bit-exact"
    lat_ms = np.array([(rec["done"] - rec["sched"]) * 1e3
                       for _, rec in outs])
    served_bytes = sum(arrays[rec["idx"]].nbytes for _, rec in outs)
    return lat_ms, len(dispatch_log), served_bytes, t_serve


def run(n_requests: int = 96, n_tenants: int = 6, n_unique: int = 24,
        kb_per_blob: int = 16, rate_per_tenant: float = 120.0,
        chunk_bytes: int = 4 * 1024, seed: int = 0,
        max_delay_ms: float = 4.0, cache_mb: int = 64):
    arrays, blobs = build_pool(n_unique, kb_per_blob, chunk_bytes, seed)
    traces = build_trace(n_requests, n_tenants, n_unique, rate_per_tenant,
                         seed)
    engine = CodagEngine(EngineConfig())

    # priming pass on a throwaway service: jit caches are process-global,
    # so this pays every window-bucket compilation ONCE while the
    # monitoring listener attributes it — compile time becomes its own
    # metric (serving/compile_ms) instead of polluting the cold-pass
    # latency percentiles.  With tuning.enable_compile_cache() active the
    # same number directly shows the persistent cache's cold-start win.
    _ensure_compile_listener()
    mark = len(_compile_secs)
    with DecompressionService(engine, max_delay_ms=max_delay_ms,
                              idle_ms=max_delay_ms / 2,
                              cache_bytes=0) as svc_prime:
        _serve_trace(svc_prime, traces, blobs, arrays)
    compile_ms = sum(_compile_secs[mark:]) * 1e3

    svc = DecompressionService(engine, max_delay_ms=max_delay_ms,
                               idle_ms=max_delay_ms / 2,
                               cache_bytes=cache_mb << 20)
    # pass 1 is cold for the SERVICE (empty decoded-blob cache) but
    # compile-free after priming; pass 2 replays the same offered load in
    # steady state: shape buckets hit the jit cache and repeated blobs hit
    # the decoded-blob cache.
    mark = len(_compile_secs)
    lat_cold, disp_cold, served_bytes, t_cold = _serve_trace(
        svc, traces, blobs, arrays)
    residual_compile_ms = sum(_compile_secs[mark:]) * 1e3
    lat_steady, disp_steady, _, t_steady = _serve_trace(
        svc, traces, blobs, arrays)
    svc_stats = svc.stats()
    svc.close()

    # baseline: synchronous one-dispatch-per-blob loop over the same trace
    flat_idxs = [idx for tr in traces for _, idx in tr]
    for idx in flat_idxs[:1]:
        engine.decompress(blobs[idx])    # warm the per-blob jit path too
    t0 = time.perf_counter()
    for idx in flat_idxs:
        engine.decompress(blobs[idx])
    t_loop = time.perf_counter() - t0

    amp = (disp_cold + disp_steady) / max(1, 2 * n_requests)
    rows = [
        ("serving/n_requests", n_requests, "per pass (2 passes)"),
        ("serving/n_tenants", n_tenants, ""),
        ("serving/unique_blobs", n_unique, ""),
        ("serving/served_MB", served_bytes / 1e6, ""),
        ("serving/dispatches/cold", disp_cold, ""),
        ("serving/dispatches/steady", disp_steady, ""),
        ("serving/dispatch_amplification", amp,
         "vs 1.0 per-blob baseline"),
        ("serving/windows", svc_stats.windows, ""),
        ("serving/blobs_per_window", svc_stats.blobs_per_window, ""),
        ("serving/dispatches_per_window", svc_stats.dispatches_per_window, ""),
        ("serving/cache_hit_rate", svc_stats.cache_hit_rate, ""),
        ("serving/compile_ms", round(compile_ms, 2),
         "backend-compile time of the serving path (priming pass)"),
        ("serving/compile_ms/residual_cold", round(residual_compile_ms, 2),
         "compile leaking into the cold pass after priming"),
        ("serving/latency_p50_ms/cold", float(np.percentile(lat_cold, 50)),
         "compile-free: jit primed, decoded-blob cache empty"),
        ("serving/latency_p99_ms/cold", float(np.percentile(lat_cold, 99)), ""),
        ("serving/latency_p50_ms", float(np.percentile(lat_steady, 50)),
         "steady state"),
        ("serving/latency_p99_ms", float(np.percentile(lat_steady, 99)),
         "steady state"),
        ("serving/throughput_MBps/service", served_bytes / t_steady / 1e6, ""),
        ("serving/throughput_MBps/per_blob", served_bytes / t_loop / 1e6, ""),
        ("serving/speedup_vs_per_blob", t_loop / t_steady, ""),
    ]
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI: finishes in well under a minute")
    ap.add_argument("--n-requests", type=int, default=96)
    ap.add_argument("--n-tenants", type=int, default=6)
    ap.add_argument("--n-unique", type=int, default=24)
    ap.add_argument("--kb-per-blob", type=int, default=16)
    ap.add_argument("--rate", type=float, default=120.0,
                    help="offered load per tenant, requests/s")
    ap.add_argument("--out", default=None, help="also write a JSON artifact")
    args = ap.parse_args()
    if args.smoke:
        args.n_requests, args.n_tenants = 40, 4
        args.n_unique, args.kb_per_blob = 10, 8
        args.rate = 200.0

    rows = run(args.n_requests, args.n_tenants, args.n_unique,
               args.kb_per_blob, args.rate)
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value},{derived}")

    if args.out:
        cfg = {"n_requests": args.n_requests, "n_tenants": args.n_tenants,
               "n_unique": args.n_unique, "kb_per_blob": args.kb_per_blob,
               "rate_per_tenant": args.rate, "smoke": bool(args.smoke)}
        print(f"# wrote {write_bench_json(args.out, 'serving', cfg, rows)}")


if __name__ == "__main__":
    main()
