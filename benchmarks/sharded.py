"""Single-device vs mesh-sharded decode throughput + per-device dispatches.

The CODAG claim scaled out: a mesh of D devices is D independent
decompressors, and the sharded plan executor
(``core.plan.DecodePlan.execute_sharded``) row-partitions every fused
group's chunk table across them.  This suite measures, on an
``ndev``-virtual-CPU-device child process:

  * decode throughput of one staged plan, single device vs the full mesh,
  * the per-device dispatch counts of a multi-device
    ``DecompressionService`` window (round-robin group→device assignment).

Virtual CPU devices share the same physical cores, so the throughput
column is a correctness-shaped smoke number on CI, not a speedup claim —
the interesting rows are the dispatch-accounting ones.

Because device count must be fixed before jax initializes, the parent
``run()`` spawns a child with ``XLA_FLAGS=--xla_force_host_platform_
device_count=<ndev>`` and parses its CSV rows.

    PYTHONPATH=src python -m benchmarks.sharded [--smoke] [--out FILE.json]
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _child(n_arrays: int, kb_per_array: int, iters: int, ndev: int) -> list:
    import time

    import jax
    import numpy as np

    from repro.core import api, server
    from repro.core import plan as plan_mod
    from repro.core.engine import CodagEngine, EngineConfig
    from repro.launch import mesh as mesh_lib

    assert len(jax.devices()) >= ndev, (len(jax.devices()), ndev)
    mesh = mesh_lib.make_decode_mesh(ndev)
    engine = CodagEngine(EngineConfig())
    rng = np.random.default_rng(0)
    elems = max(1024, kb_per_array * 1024 // 4)
    arrays = [np.repeat(rng.integers(0, 90, max(4, elems // 40))
                        .astype(np.uint32),
                        rng.integers(1, 80, max(4, elems // 40)))[:elems]
              for _ in range(n_arrays // 2)]
    arrays += [rng.integers(0, 127, elems).astype(np.uint32)
               for _ in range(n_arrays - n_arrays // 2)]
    codecs = ["rle_v2"] * (n_arrays // 2) + \
             ["bitpack"] * (n_arrays - n_arrays // 2)
    cas = api.compress_many(arrays, codecs, chunk_bytes=16 * 1024)
    blobs = [b for ca in cas for b in ca.blobs]
    total_bytes = sum(a.nbytes for a in arrays)

    plan = plan_mod.DecodePlan.build(blobs)

    def timeit(fn):
        for o in fn():                     # warmup (stage + trace)
            o.block_until_ready()
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            for o in fn():
                o.block_until_ready()
            times.append(time.perf_counter() - t0)
        return float(np.median(times))

    t_single = timeit(lambda: plan.execute_device(engine))
    t_sharded = timeit(lambda: plan.execute_sharded(mesh, engine=engine))
    single = plan.execute_device(engine)
    sharded = plan.execute_sharded(mesh, engine=engine)
    for s, m in zip(single, sharded):
        assert np.array_equal(np.asarray(s), np.asarray(m))

    # per-device dispatch accounting through the multi-device service
    with server.DecompressionService(engine, devices=jax.devices()[:ndev],
                                     cache_bytes=0,
                                     bucket_shapes=False) as svc:
        for f in svc.submit_many(blobs):
            f.result(timeout=600)
        st = svc.stats()

    rows = [
        ("sharded/ndev", ndev, ""),
        ("sharded/n_arrays", n_arrays, ""),
        ("sharded/total_MB", total_bytes / 1e6, ""),
        ("sharded/groups", plan.num_dispatches, ""),
        ("sharded/throughput_MBps/single", total_bytes / t_single / 1e6, ""),
        ("sharded/throughput_MBps/mesh", total_bytes / t_sharded / 1e6,
         t_single / t_sharded),
        ("sharded/service/dispatches", st.dispatches, ""),
        ("sharded/service/devices_used", len(st.device_dispatches),
         len(st.device_dispatches) / max(1, min(ndev, st.dispatches))),
    ]
    rows += [(f"sharded/service/dispatches/{dev}", n, "")
             for dev, n in sorted(st.device_dispatches.items())]
    return rows


def run(n_arrays: int = 8, kb_per_array: int = 64, iters: int = 3,
        ndev: int = 8) -> list:
    """Spawn the fixed-device-count child and parse its CSV rows back."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src") + os.pathsep + _ROOT
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.sharded", "--as-child",
         "--n-arrays", str(n_arrays), "--kb-per-array", str(kb_per_array),
         "--iters", str(iters), "--ndev", str(ndev)],
        capture_output=True, text=True, env=env, cwd=_ROOT, timeout=1200)
    if r.returncode != 0:
        raise RuntimeError(f"sharded bench child failed:\n{r.stderr[-4000:]}")
    rows = []
    for line in r.stdout.splitlines():
        parts = line.strip().split(",")
        if len(parts) == 3 and parts[0].startswith("sharded/"):
            name, value, derived = parts
            rows.append((name, float(value), derived))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI: finishes in under a minute")
    ap.add_argument("--as-child", action="store_true",
                    help=argparse.SUPPRESS)   # internal: run inside the
    #                                           forced-device-count process
    ap.add_argument("--n-arrays", type=int, default=8)
    ap.add_argument("--kb-per-array", type=int, default=64)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--ndev", type=int, default=8)
    ap.add_argument("--out", default=None, help="also write a JSON artifact")
    args = ap.parse_args()
    if args.smoke:
        args.n_arrays, args.kb_per_array, args.iters = 4, 8, 1

    if args.as_child:
        rows = _child(args.n_arrays, args.kb_per_array, args.iters,
                      args.ndev)
    else:
        rows = run(args.n_arrays, args.kb_per_array, args.iters, args.ndev)
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value},{derived}")

    if args.out and not args.as_child:
        from benchmarks.common import write_bench_json
        cfg = {"n_arrays": args.n_arrays, "kb_per_array": args.kb_per_array,
               "iters": args.iters, "ndev": args.ndev,
               "smoke": bool(args.smoke)}
        print(f"# wrote {write_bench_json(args.out, 'sharded', cfg, rows)}")


if __name__ == "__main__":
    main()
