"""Table V analogue: compression ratios + average compressed symbol length
across the Table IV-style dataset suite."""
from __future__ import annotations

import numpy as np

from benchmarks.common import compressed_corpus
from repro.core import format as fmt

CODECS = (fmt.RLE_V1, fmt.RLE_V2, fmt.TDEFLATE)


def _avg_symbol_len(blob) -> float:
    """uncompressed bytes per compressed group/token (Table V right half)."""
    # groups estimated from the encoder's control structure: sample-decode
    # group count by parsing headers host-side (cheap numpy walk).
    total_groups = 0
    for i in range(blob.num_chunks):
        row = blob.comp[i, : int(blob.comp_lens[i])]
        pos, groups = 0, 0
        if blob.codec == fmt.RLE_V1:
            w = blob.width
            while pos < len(row):
                c = int(row[pos])
                pos += 1 + (w if c < 128 else (256 - c) * w)
                groups += 1
        elif blob.codec == fmt.RLE_V2:
            w = blob.width
            while pos < len(row):
                h = int(row[pos])
                mode, f = h >> 6, h & 63
                if mode == 2:
                    pos += 1 + (f + 1) * w
                elif mode == 1:
                    pos += 1 + 2 * w
                elif mode == 3:
                    pos += 2 + w
                else:
                    pos += 1 + w
                groups += 1
        else:
            return float("nan")
        total_groups += max(groups, 1)
    return blob.uncompressed_bytes / max(total_groups, 1)


def run(size_mb: float = 1.0):
    corpus = compressed_corpus(size_mb, CODECS)
    rows = []
    for codec in CODECS:
        for name, ca in corpus[codec].items():
            rows.append((f"ratio/{codec}/{name}", ca.ratio, 0))
            if codec != fmt.TDEFLATE:
                asl = float(np.mean([_avg_symbol_len(b) for b in ca.blobs]))
                rows.append((f"symlen/{codec}/{name}", asl, 0))
    return rows
