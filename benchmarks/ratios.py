"""Table V analogue: compression ratios + average compressed symbol length
across the Table IV-style dataset suite, for every registered codec."""
from __future__ import annotations

import numpy as np

from benchmarks.common import codec_matrix, compressed_corpus
from repro.core import registry


def _avg_symbol_len(blob) -> float:
    """uncompressed bytes per compressed group/token (Table V right half).

    Uses the codec's registered host-side header walk; codecs without one
    (token-structured streams like tdeflate) report NaN.
    """
    count = registry.get(blob.codec).count_groups
    if count is None:
        return float("nan")
    total_groups = 0
    for i in range(blob.num_chunks):
        row = blob.comp[i, : int(blob.comp_lens[i])]
        total_groups += max(count(row, blob.width), 1)
    return blob.uncompressed_bytes / max(total_groups, 1)


def run(size_mb: float = 1.0):
    corpus = compressed_corpus(size_mb, codec_matrix())
    rows = []
    for codec in codec_matrix():
        for name, ca in corpus[codec].items():
            rows.append((f"ratio/{codec}/{name}", ca.ratio, 0))
            if registry.get(codec).count_groups is not None:
                asl = float(np.mean([_avg_symbol_len(b) for b in ca.blobs]))
                rows.append((f"symlen/{codec}/{name}", asl, 0))
    return rows
